//! The epoch-driven scheduling loop, built around persistent, delta-aware
//! state: the [`JobLedger`] (id-indexed jobs, arrival heap, running set,
//! and the dirty set driving selective predictor refits), the
//! [`SchedContext`] (previous grant + this epoch's materialized gain
//! table, for policy warm starts) and the node pool's placement-diff
//! application.
//!
//! ## The deterministic parallel epoch pipeline
//!
//! With `threads > 1` the data-parallel stages of an epoch — the
//! dirty-set predictor refits, the gain-table build, and (in sharded
//! mode) the per-shard decisions — run on a persistent
//! [`WorkerPool`] created once in [`Coordinator::new`]; tasks are pinned
//! to workers in stable submission order, so no per-epoch thread spawns
//! and no scheduling-order dependence. Determinism is by construction:
//!
//! * each task works on *disjoint, preassigned* slots (a predictor is
//!   refit by exactly one worker; a gain-table row is filled by exactly
//!   one worker into its fixed arena range; a shard's policy, context
//!   and grant buffer are touched only by that shard's task), so no
//!   output depends on which worker ran first;
//! * task results merge in stable job-id/shard-id order (predictors
//!   return to their ledger rows by id; table rows were laid out in
//!   request order before any worker started; shard grants scatter back
//!   through each shard's fixed index list), and the only cross-task
//!   aggregates are integer counts;
//! * only plain data crosses threads: `&mut OnlinePredictor` rows (the
//!   predictor is owned data, `Send + Sync` by construction — asserted
//!   at compile time in `predictor/online.rs`), `&mut [f64]` arena
//!   slices, and `&mut Shard` state. The job rows themselves, which
//!   hold non-`Sync` [`LossSource`] boxes, never leave the coordinator
//!   thread.
//!
//! Hence `slaq-det` runs are bit-identical at any thread count
//! (property-tested below), and `threads: 1` remains the serial
//! reference path — direct oracle calls inside the allocator, no tables,
//! no worker threads.
//!
//! ## Sharded epochs and the budget broker
//!
//! With [`CoordinatorConfig::sharded`] the job population is partitioned
//! across per-zone shards keyed by the topology (`job id mod zones` —
//! stable, order-preserving within each shard). Each shard owns a full
//! policy instance, its own [`SchedContext`] (previous grants + gain
//! table), and a persistent grant buffer, and runs the existing
//! warm-start/gain-table/CELF path over only its own jobs against a core
//! *budget*; a top-level broker re-splits total capacity across the
//! budgets every [`CoordinatorConfig::broker_epochs`] epochs from each
//! shard's aggregate marginal-gain curve
//! ([`crate::sched::rebalance_budgets`]). The common-case epoch is
//! therefore O(shard) work done in parallel, not O(cluster). With one
//! shard the broker always grants the whole capacity, so a flat-topology
//! sharded run is bit-identical to the unsharded coordinator
//! (property-tested below).

use super::job::{JobState, JobSpec, Job};
use super::ledger::JobLedger;
use super::pool::WorkerPool;
use super::source::LossSource;
use super::trace::{EpochEntry, EpochRecord, JobTrace, Trace};
use super::wal::{
    compact_wal, config_bytes, read_snapshot, read_wal, truncate_wal, DurableState,
    SnapshotView, WalEpoch, WalRecord, WalWriter, SNAP_FILE, WAL_FILE,
};
use crate::cluster::{
    ClusterSpec, CostModel, FaultAction, FaultSpec, LocalityModel, NodePool, TopologySpec,
    TransitionModel,
};
use crate::predictor::OnlinePredictor;
use crate::sched::{
    policy_by_name, rebalance_budgets, Allocation, GainModel, GainTable, JobRequest, Policy,
    SchedContext, ShardDemand,
};
use crate::util::codec::corrupt;
use std::collections::{BTreeMap, BTreeSet};
use std::io;
use std::path::Path;
use std::time::Instant;

/// Injectable kill points for the crash-recovery test harness
/// (`testkit::crash`). A coordinator with a crash point set aborts
/// [`Coordinator::step_epoch`] at that point — mid-epoch, after
/// externally-invisible work has begun but before the epoch becomes
/// durable — exactly as a `kill -9` there would, and is then discarded
/// by the harness. Recovery must land on the previous epoch boundary
/// either way.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CrashPoint {
    /// Die between the predictor-refit stage and the allocation decision:
    /// in-memory predictors have already advanced and the dirty set is
    /// drained, but nothing reached disk.
    AfterRefit,
    /// Die after the epoch fully executed in memory — grants applied,
    /// jobs advanced, completions retired — but before its WAL record was
    /// appended. The epoch never becomes durable and recovery replays to
    /// the previous boundary.
    BeforeWalAppend,
}

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Cluster topology.
    pub cluster: ClusterSpec,
    /// Rack/zone structure over the cluster's nodes. The default
    /// ([`TopologySpec::Flat`]) is the legacy single-rack pool, on which
    /// the whole locality layer is provably inert.
    pub topology: TopologySpec,
    /// Per-iteration slowdown for placements that straddle racks,
    /// consumed by both the simulator's iteration clock and the
    /// scheduler's gain oracles. At one rack the factor is always 1.0.
    pub locality: LocalityModel,
    /// When true (the default) the node pool's grow path prefers racks a
    /// job already occupies; `false` keeps the legacy global
    /// `(free, node)` order — the locality-blind baseline the
    /// `exp::locality` scenario compares against.
    pub locality_aware: bool,
    /// Scheduling epoch length `T` (virtual seconds). The paper uses
    /// short epochs (a few seconds) for continuous rebalancing.
    pub epoch_secs: f64,
    /// Treat jobs with almost no loss history optimistically (every
    /// achievable iteration worth the maximum normalized delta). Disable
    /// only for the cold-start ablation.
    pub cold_start_optimism: bool,
    /// Sync only the predictors of jobs that received loss samples since
    /// the last epoch (the ledger's dirty set) instead of sweeping every
    /// active job. Equivalent to the sweep — `refresh_fit` is a no-op on a
    /// clean predictor — and property-tested so; disable only for the
    /// equivalence property itself or an ablation.
    pub selective_refits: bool,
    /// Defer refits for dirty jobs whose newest samples the current fit
    /// already explains (prediction error within the fit's own residual;
    /// see [`crate::predictor::OnlinePredictor::refresh_fit_deferrable`]).
    /// Off by default: it trades bit-exact fit freshness for a smaller
    /// refit bill, so the quality-fidelity suite pins its behaviour
    /// separately.
    pub refit_amortization: bool,
    /// Worker threads for the epoch pipeline's data-parallel stages (the
    /// dirty-set predictor refits, the gain-table build, and the
    /// per-shard decisions in sharded mode). `0` (the default) resolves
    /// to the machine's available parallelism at coordinator
    /// construction; `1` keeps the fully serial reference path — oracle
    /// calls inside the allocator, no materialized tables, no worker
    /// threads. Deterministic policies produce bit-identical runs at
    /// every setting (see the module docs).
    pub threads: usize,
    /// Partition the job population across per-zone shard schedulers
    /// (one shard per topology zone, `job id mod zones`), each running
    /// the full policy path over only its own jobs against a broker-set
    /// core budget. Off by default — the flat single-allocator path. On
    /// a single-zone topology the sharded pipeline is bit-identical to
    /// the flat one (property-tested in this module).
    pub sharded: bool,
    /// Broker cadence for sharded mode: per-shard core budgets are
    /// rebalanced from the shards' aggregate marginal-gain curves every
    /// this many epochs (the first epoch always rebalances). Between
    /// rebalances the budgets stay fixed, so common-case epochs do no
    /// cross-shard work.
    pub broker_epochs: usize,
    /// Checkpoint cadence for restart pricing under faults: at the start
    /// of every `checkpoint_epochs`-th epoch each running job pins its
    /// current iteration as the restart point. A job evicted by a node
    /// failure re-does the iterations since that pin (as wall-clock debt
    /// consuming epoch time without advancing quality) once it regains
    /// cores. Irrelevant — and provably inert — when `faults` is empty.
    pub checkpoint_epochs: usize,
    /// Deterministic node-failure schedule applied at epoch boundaries
    /// (crash-stop, transient blackout, correlated rack outage; see
    /// [`FaultSpec`]). Empty by default: every fault hook in the epoch
    /// loop is a provable no-op on an empty spec, keeping fault-free
    /// traces bitwise identical to pre-fault builds.
    pub faults: FaultSpec,
    /// Cost of *voluntarily* changing a grant: any shrink (or cross-rack
    /// move) rewinds the job to its last checkpoint and burns
    /// restore/warmup iterations as restart debt on the simulator clock
    /// (see [`TransitionModel`]). The zero-cost default is provably
    /// inert — the voluntary-restart stage and the planner penalty are
    /// both gated on [`TransitionModel::is_free`], keeping default
    /// traces bitwise identical to pre-transition-model builds.
    pub transition: TransitionModel,
    /// When true (the default) and `transition` is non-free, the gain
    /// views expose a per-job transition penalty through
    /// [`crate::sched::GainModel::net_gain`], so the planner only shrinks
    /// a job when the quality gained elsewhere clears the restart cost.
    /// `false` keeps charging restarts in the simulator while the
    /// planner ignores them — the "aggressive" arm the `exp::elastic`
    /// scenario compares against.
    pub price_transitions: bool,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            cluster: ClusterSpec::paper_testbed(),
            topology: TopologySpec::Flat,
            locality: LocalityModel::default(),
            locality_aware: true,
            epoch_secs: 3.0,
            cold_start_optimism: true,
            selective_refits: true,
            refit_amortization: false,
            threads: 0,
            sharded: false,
            broker_epochs: 8,
            checkpoint_epochs: 4,
            faults: FaultSpec::none(),
            transition: TransitionModel::default(),
            price_transitions: true,
        }
    }
}

/// Gain oracle the coordinator exposes to the policy for one job.
///
/// `gain(a)` = predicted normalized loss reduction over the next epoch with
/// `a` cores = `f(k) − f(k + Δk(a))` where `Δk(a)` comes from the job's BSP
/// cost model and `f` from its fitted convergence curve.
///
/// Cold start: a job with fewer than 3 loss observations has no usable fit;
/// SLAQ treats it optimistically (every achievable iteration is worth the
/// maximum normalized delta of 1.0), which front-loads resources into new
/// jobs — exactly the behaviour the paper wants for fresh arrivals.
///
/// The oracle is a plain *view* (`&OnlinePredictor` plus copied cost-model
/// scalars) rather than a `&Job` borrow: `Job` carries its boxed
/// [`LossSource`] (not `Sync`), while this view is `Sync` and can be
/// handed to the gain-table build workers.
struct JobGain<'a> {
    predictor: &'a OnlinePredictor,
    cost: CostModel,
    credit: f64,
    cap: u32,
    window: f64,
    cold_start_optimism: bool,
    /// Locality slowdown of the job's placement entering this epoch
    /// (rack span → iteration-time factor; 1.0 on flat topologies), so
    /// the predicted quality-per-epoch genuinely feels fragmentation.
    slowdown: f64,
    /// Degraded-mode fallback: the job's predictor is quarantined (a run
    /// of rejected loss reports) or its confidence has collapsed, so its
    /// fitted curve cannot be trusted. The view replaces the curve with a
    /// conservative fair-share floor (see `gain`), and `cap` is clamped
    /// to `fair_share` so the job can never outbid its way past an even
    /// split of surviving capacity.
    degraded: bool,
    /// Cores the degraded curve saturates at (surviving capacity divided
    /// by the active-job count; ≥ 1). Unused while `degraded` is false.
    fair_share: u32,
    /// Cores the job holds entering this epoch (its `prev_cores` request
    /// field): the reference point for the transition penalty below.
    prev: u32,
    /// Transition penalty in normalized-reduction units: what shrinking
    /// this job below `prev` would cost it (checkpoint rewind + restore
    /// and warmup iterations + the checkpoint write, pushed through the
    /// job's own predicted-reduction curve). Materialized once per job
    /// per epoch by the coordinator; 0.0 whenever pricing is off, so
    /// `net_gain` degenerates to `gain` bit for bit.
    penalty: f64,
}

/// Scale of the degraded-mode gain curve: small enough that a degraded
/// job never outbids any healthy job with genuinely positive predicted
/// reduction, but strictly positive so work-conserving policies still
/// hand it spare cores ahead of nothing at all.
const DEGRADED_EPS: f64 = 1e-9;

impl<'a> JobGain<'a> {
    fn new(job: &'a Job, window: f64, cold_start_optimism: bool, slowdown: f64) -> Self {
        Self {
            predictor: &job.predictor,
            cost: job.spec.cost,
            credit: job.credit,
            cap: job.effective_max_cores(),
            window,
            cold_start_optimism,
            slowdown,
            degraded: false,
            fair_share: 0,
            prev: job.cores,
            penalty: 0.0,
        }
    }

    /// The job's core cap (also its gain-table row length).
    fn cap(&self) -> u32 {
        self.cap
    }
}

impl GainModel for JobGain<'_> {
    fn gain(&self, cores: u32) -> f64 {
        if cores == 0 {
            return 0.0;
        }
        if self.degraded {
            // Fair-share floor: strictly increasing with geometrically
            // shrinking (hence CELF-friendly, submodular) marginals up
            // to the fair share, flat beyond it. Epsilon-scaled so any
            // healthy job with real predicted reduction wins first.
            let c = cores.min(self.fair_share.max(1));
            return DEGRADED_EPS * (1.0 - 0.5f64.powi(c as i32));
        }
        // Shared definition with `Job::iterations_achievable_f` (and the
        // same scaled clock `Job::advance_with_locality` runs on), so
        // table rows (filled from this view) and the serial oracle path
        // are bit-identical and can never drift from the job progress
        // model.
        let dk =
            self.cost
                .fractional_iterations_scaled(self.window, cores, self.credit, self.slowdown);
        if dk <= 0.0 {
            return 0.0;
        }
        if self.cold_start_optimism && self.predictor.history().len() < 3 {
            return dk;
        }
        self.predictor.predicted_normalized_reduction(dk)
    }

    /// Transition-priced gain: candidate grants below the grant held
    /// entering the epoch (a shrink, which forces a checkpoint restart)
    /// are charged the materialized `penalty`. The guard is a branch, not
    /// arithmetic, so with a zero penalty (pricing off, free transition
    /// model, or a fresh arrival) every value is bit-for-bit the plain
    /// gain. `cores == 0` stays at gain 0 by convention — policies treat
    /// an empty grant as the zero baseline, and the simulator charges the
    /// actual restart debt regardless of what the planner priced.
    fn net_gain(&self, prev_cores: u32, cores: u32) -> f64 {
        let g = self.gain(cores);
        if self.penalty == 0.0 || prev_cores == 0 || cores == 0 || cores >= prev_cores {
            return g;
        }
        g - self.penalty
    }
}

/// Reusable per-epoch buffers. With these (plus the gain arena in the
/// [`SchedContext`] and the policy's own heap scratch), a steady-state
/// `step_epoch` allocates little beyond what escapes into the trace —
/// the epoch record with its entries and the grant vector — plus the
/// borrow-scoped gain-view and request vectors, which cannot persist
/// across epochs because they borrow the ledger.
#[derive(Default)]
struct EpochScratch {
    /// Running ids (ascending).
    active: Vec<u64>,
    /// Drained dirty ids (ascending).
    dirty: Vec<u64>,
    /// `(job id, cores)` placement targets.
    targets: Vec<(u64, u32)>,
    /// Epoch-start losses, parallel to `active`.
    losses: Vec<f64>,
    /// Post-placement rack spans, parallel to `active` (computed once
    /// per epoch, shared by the trace entries and the advance loop).
    spans: Vec<u32>,
    /// Predictors moved out of the ledger for a sharded refit (empty
    /// between epochs; keeps its capacity).
    refit_batch: Vec<(u64, OnlinePredictor)>,
    /// The epoch's flat grant vector, written in place by the policy's
    /// out-param path (or merged from the shard grants), so steady-state
    /// epochs stop allocating a fresh grant per decision.
    grant: Allocation,
    /// Per-chunk refit counts for the pooled refit stage (threads-sized).
    refit_counts: Vec<usize>,
}

/// One per-zone shard of the sharded coordinator: a full policy instance
/// plus the persistent state its decisions evolve over. Every field is
/// touched only by this shard's pipeline task (or the coordinator thread
/// between phases), which is what makes the parallel decision phase
/// deterministic.
struct Shard {
    /// This shard's own policy instance (same name/variant as the
    /// coordinator's policy, resolved via [`policy_by_name`]).
    policy: Box<dyn Policy>,
    /// Shard-local scheduling context: previous grants and the shard's
    /// materialized gain table.
    ctx: SchedContext,
    /// Core budget set by the broker at the last rebalance.
    budget: u32,
    /// Persistent grant buffer for the out-param decision path.
    grant: Allocation,
    /// Positions into this epoch's `active` list owned by the shard
    /// (ascending — the stable merge order).
    idx: Vec<usize>,
}

/// The SLAQ coordinator: owns the job ledger, the node pool, the policy
/// and the persistent scheduling context.
pub struct Coordinator {
    cfg: CoordinatorConfig,
    policy: Box<dyn Policy>,
    pool: NodePool,
    ledger: JobLedger,
    sched_ctx: SchedContext,
    time: f64,
    epochs: Vec<EpochRecord>,
    /// Resolved worker-thread count (`cfg.threads`, with 0 resolved to
    /// the machine's available parallelism at construction).
    threads: usize,
    /// Persistent worker pool for the pipeline's data-parallel stages
    /// (`Some` iff `threads > 1`), created once here and joined on drop —
    /// no per-epoch thread spawns.
    workers: Option<WorkerPool>,
    /// Per-zone shards (empty unless `cfg.sharded`).
    shards: Vec<Shard>,
    scratch: EpochScratch,
    /// Durable half (state dir + open WAL + snapshot cadence) — `Some`
    /// iff this coordinator was built by [`Coordinator::with_persistence`]
    /// or [`Coordinator::recover_state`].
    durable: Option<DurableState>,
    /// Injected kill point for the crash-recovery harness.
    crash_point: Option<CrashPoint>,
    /// Fault-displaced jobs waiting out a placement backoff:
    /// id → (epoch the job may request cores again, current backoff in
    /// epochs). A parked job stays in the ledger's running set (its state
    /// must survive replay) but requests zero cores until its park
    /// expires; a failed retry re-parks it with doubled backoff (capped).
    parked: BTreeMap<u64, (u64, u32)>,
    /// Jobs currently served by the degraded-mode gain floor (quarantined
    /// predictor or collapsed confidence). Kept only to detect
    /// healthy→degraded transitions; the flag itself is recomputed every
    /// epoch from predictor state, so this set is derivable — and is
    /// re-derived, not persisted, on recovery.
    degraded_now: BTreeSet<u64>,
    /// Cumulative count of healthy→degraded transitions — the loud signal
    /// that the scheduler stopped trusting some job's quality reports.
    degraded_transitions: u64,
    /// Cumulative count of epochs in which at least one fault-displaced
    /// (or park-expired) job could not be re-placed. Recorded per epoch
    /// in [`EpochRecord::failed_epochs`].
    failed_epochs: u32,
    /// One [`EpochNotice`] per completed epoch, in order — the full
    /// subscriber-visible history. Persisted in the snapshot and
    /// re-derived identically by WAL replay, so a subscriber attaching
    /// to a recovered service misses no epochs.
    notices: Vec<EpochNotice>,
}

/// Boundary-state summary of one completed epoch, broadcast to
/// [`crate::coordinator::CoordinatorService`] subscribers and retained
/// (per epoch, in order) as the coordinator's notice history.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EpochNotice {
    /// Epochs completed so far (this epoch included).
    pub epoch: usize,
    /// Virtual time after the epoch.
    pub time: f64,
    /// Jobs still running after the epoch.
    pub active: usize,
    /// Jobs completed so far, in total.
    pub completed: usize,
}

impl Coordinator {
    /// New coordinator with the given policy.
    ///
    /// In sharded mode ([`CoordinatorConfig::sharded`]) the policy's
    /// [`Policy::name`] must resolve through [`policy_by_name`] so every
    /// shard can own its own instance of the same variant; the built-in
    /// policies all do.
    pub fn new(cfg: CoordinatorConfig, policy: Box<dyn Policy>) -> Self {
        let topology = cfg.topology.build(cfg.cluster.nodes);
        let mut pool = NodePool::with_topology(cfg.cluster, topology.clone());
        pool.set_locality_aware(cfg.locality_aware);
        let threads = if cfg.threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            cfg.threads
        };
        let workers = (threads > 1).then(|| WorkerPool::new(threads));
        let shards = if cfg.sharded {
            // One shard per topology zone, each seeded with its zone's
            // share of the cluster (zone node count × cores per node)
            // until the broker's first demand-driven rebalance.
            (0..topology.zones())
                .map(|z| Shard {
                    policy: policy_by_name(policy.name()).unwrap_or_else(|| {
                        panic!(
                            "sharded mode needs a registry policy, got {:?}",
                            policy.name()
                        )
                    }),
                    ctx: SchedContext::new(),
                    budget: topology.zone_nodes(z) * cfg.cluster.cores_per_node,
                    grant: Allocation::default(),
                    idx: Vec::new(),
                })
                .collect()
        } else {
            Vec::new()
        };
        Self {
            cfg,
            policy,
            pool,
            ledger: JobLedger::new(),
            sched_ctx: SchedContext::new(),
            time: 0.0,
            epochs: Vec::new(),
            threads,
            workers,
            shards,
            scratch: EpochScratch::default(),
            durable: None,
            crash_point: None,
            parked: BTreeMap::new(),
            degraded_now: BTreeSet::new(),
            degraded_transitions: 0,
            failed_epochs: 0,
            notices: Vec::new(),
        }
    }

    /// New durable coordinator: every submission, cancellation and epoch
    /// is logged to an append-only WAL under `dir` (created if missing),
    /// and the full mutable state is snapshotted every `snapshot_every`
    /// epochs. A crashed durable coordinator is rebuilt bit-identically
    /// by [`Coordinator::recover_state`] on the same directory.
    ///
    /// This starts a *fresh* run: any previous WAL/snapshot in `dir` is
    /// removed. The policy must resolve through [`policy_by_name`] (it is
    /// re-instantiated by name on recovery) and every submitted source
    /// must implement [`LossSource::descriptor`].
    pub fn with_persistence(
        cfg: CoordinatorConfig,
        policy: Box<dyn Policy>,
        dir: &Path,
        snapshot_every: usize,
    ) -> io::Result<Self> {
        assert!(snapshot_every >= 1, "snapshot cadence must be >= 1 epoch");
        assert!(
            policy_by_name(policy.name()).is_some(),
            "durable mode needs a registry policy, got {:?}",
            policy.name()
        );
        std::fs::create_dir_all(dir)?;
        match std::fs::remove_file(dir.join(SNAP_FILE)) {
            Ok(()) => {}
            Err(e) if e.kind() == io::ErrorKind::NotFound => {}
            Err(e) => return Err(e),
        }
        let mut wal = WalWriter::create(&dir.join(WAL_FILE))?;
        wal.append(&WalRecord::Genesis {
            cfg: cfg.clone(),
            policy: policy.name().to_string(),
            snapshot_every: snapshot_every as u64,
        })?;
        let mut c = Self::new(cfg, policy);
        c.durable = Some(DurableState { dir: dir.to_path_buf(), wal, snapshot_every });
        Ok(c)
    }

    /// Rebuild a durable coordinator from its state directory after a
    /// crash: load the snapshot if one exists, then replay the WAL tail
    /// past the snapshot's high-water mark. For a deterministic policy
    /// the recovered coordinator is *bit-identical* to the crashed one at
    /// its last durable epoch boundary — same ledger, predictors,
    /// placements, contexts and trace — so resuming it reproduces the
    /// uninterrupted run exactly (property-tested in `testkit::crash`).
    ///
    /// A torn final WAL record (crash mid-append) is dropped and the file
    /// truncated; a complete record with a bad checksum fails loudly,
    /// as do any replay-verification mismatches (each replayed epoch is
    /// cross-checked against its logged grants, losses, spans and
    /// completions — the at-most-once guarantee on completion effects).
    pub fn recover_state(dir: &Path) -> io::Result<Self> {
        let wal_path = dir.join(WAL_FILE);
        let readout = read_wal(&wal_path)?;
        if readout.torn {
            truncate_wal(&wal_path, readout.valid_len)?;
        }
        let snap = read_snapshot(dir)?;
        // Resolve config, policy and cadence — cross-checked byte-for-byte
        // when both the snapshot and the WAL genesis are present.
        let (cfg, policy_name, snapshot_every) = match (&snap, readout.records.first()) {
            (Some(s), Some(WalRecord::Genesis { cfg, policy, snapshot_every })) => {
                if config_bytes(&s.cfg) != config_bytes(cfg) {
                    return Err(corrupt("snapshot and WAL genesis disagree on the config"));
                }
                if s.policy != *policy || s.snapshot_every != *snapshot_every {
                    return Err(corrupt("snapshot and WAL genesis disagree on policy/cadence"));
                }
                (s.cfg.clone(), s.policy.clone(), s.snapshot_every)
            }
            (Some(s), _) => (s.cfg.clone(), s.policy.clone(), s.snapshot_every),
            (None, Some(WalRecord::Genesis { cfg, policy, snapshot_every })) => {
                (cfg.clone(), policy.clone(), *snapshot_every)
            }
            (None, Some(_)) => return Err(corrupt("WAL does not start with a genesis record")),
            (None, None) => {
                return Err(corrupt("no snapshot and no WAL genesis: nothing to recover"))
            }
        };
        let policy = policy_by_name(&policy_name).ok_or_else(|| {
            corrupt(format!("unknown policy {policy_name:?} in durable state"))
        })?;
        let wal_records = readout.records.len() as u64;
        let mut c = Self::new(cfg, policy);

        // Snapshot restore: the complete mutable state at its boundary.
        let mut skip = 0usize;
        let mut snap_high_water = 0usize;
        if let Some(s) = snap {
            snap_high_water = s.wal_records as usize;
            skip = snap_high_water.min(readout.records.len());
            c.time = s.time;
            c.epochs = s.epochs;
            c.ledger = s.ledger;
            // Re-derive the pool's dead set as of the snapshot boundary:
            // fault events are a pure function of the epoch index, and
            // the pool holds no placements yet, so the evictions are
            // vacuous (asserted) — only the dead set and the free-space
            // index change. `restore_placements` then checks itself
            // against the surviving capacity.
            if !c.cfg.faults.is_empty() {
                let mut lost: Vec<(u64, u32)> = Vec::new();
                for e in 0..c.epochs.len() as u64 {
                    for ev in c.cfg.faults.events_at(e) {
                        match ev.action {
                            FaultAction::Recover => c.pool.recover_node(ev.node),
                            FaultAction::Fail => c.pool.fail_node(ev.node, &mut lost),
                        }
                    }
                }
                assert!(lost.is_empty(), "evictions on an empty pool");
            }
            c.pool.restore_placements(&s.placements);
            c.parked = s.parked.into_iter().map(|(id, until, b)| (id, (until, b))).collect();
            c.degraded_now = s.degraded.into_iter().collect();
            c.degraded_transitions = s.degraded_transitions;
            c.failed_epochs = c.epochs.last().map(|r| r.failed_epochs).unwrap_or(0);
            c.notices = s.notices;
            c.sched_ctx.restore_grants(s.ctx_grants, s.ctx_epoch);
            if s.shards.len() != c.shards.len() {
                return Err(corrupt(format!(
                    "snapshot has {} shards, config builds {}",
                    s.shards.len(),
                    c.shards.len()
                )));
            }
            for (shard, (budget, epoch, grants)) in c.shards.iter_mut().zip(s.shards) {
                shard.budget = budget;
                shard.ctx.restore_grants(grants, epoch);
            }
        }

        // Replay the WAL tail in append order.
        for (i, rec) in readout.records.into_iter().enumerate() {
            if i < skip {
                continue;
            }
            match rec {
                WalRecord::Genesis { .. } => {
                    if i != 0 {
                        return Err(corrupt(format!("genesis record mid-log (index {i})")));
                    }
                }
                WalRecord::Submit { spec, source } => {
                    c.ledger.submit(spec, source.instantiate());
                }
                WalRecord::Cancel { id } => {
                    if !c.apply_cancel(id) {
                        return Err(corrupt(format!(
                            "logged cancel of job {id} was a no-op on replay"
                        )));
                    }
                }
                WalRecord::Epoch(ep) => c.replay_epoch(&ep)?,
            }
        }

        let stale_snapshot = snap_high_water > wal_records as usize;
        c.durable = Some(DurableState {
            dir: dir.to_path_buf(),
            wal: WalWriter::open_append(&wal_path, wal_records)?,
            snapshot_every: snapshot_every as usize,
        });
        if stale_snapshot {
            // The snapshot's WAL high-water mark exceeds what the file
            // holds (the log was emptied or rotated externally). Future
            // appends would land below the mark and a later recovery
            // would wrongly skip them — rewrite the snapshot against the
            // file as it is now.
            c.snapshot_now()?;
        }
        Ok(c)
    }

    /// Re-execute one logged epoch during recovery. The live decision
    /// phase is skipped — grants come from the log — but everything the
    /// decisions *caused* is re-run through the same code paths as
    /// [`Coordinator::step_epoch`] (activation, refits, placement diff,
    /// job advance, retirement), each stage verified against the logged
    /// record: epoch time, active set, dirty count, refit count, losses
    /// (bitwise), cross-rack moves, rack spans and the completion list.
    /// Completion effects are therefore applied at most once — replay
    /// re-derives them and cross-checks, it never double-applies.
    fn replay_epoch(&mut self, ep: &WalEpoch) -> io::Result<()> {
        let t0 = self.time;
        let window = self.cfg.epoch_secs;
        let rec = &ep.record;
        if rec.time.to_bits() != t0.to_bits() {
            return Err(corrupt(format!(
                "replay time skew: log epoch at t={}, state at t={t0}",
                rec.time
            )));
        }

        self.ledger.activate_due(t0);
        let mut active: Vec<u64> = Vec::new();
        self.ledger.running_ids_into(&mut active);
        if active.len() != rec.entries.len() || rec.active_jobs != active.len() {
            return Err(corrupt(format!(
                "replay active-set skew at t={t0}: log {} entries, state {}",
                rec.entries.len(),
                active.len()
            )));
        }
        for (e, &id) in rec.entries.iter().zip(&active) {
            if e.job != id {
                return Err(corrupt(format!(
                    "replay active-set skew at t={t0}: log job {}, state job {id}",
                    e.job
                )));
            }
        }

        // Fault boundary — identical to the live epoch's stage 2b
        // (checkpoint cadence, recoveries then failures, placement
        // eviction and restart debt), then cross-checked against the
        // logged core loss. The checkpoint pin mirrors the live gate:
        // any restart source — faults or a non-free transition model —
        // keeps the cadence.
        let epoch_no = self.epochs.len() as u64;
        let mut lost_cores = 0u32;
        let mut displaced: BTreeSet<u64> = BTreeSet::new();
        if !self.cfg.faults.is_empty() || !self.cfg.transition.is_free() {
            let cadence = self.cfg.checkpoint_epochs.max(1) as u64;
            if epoch_no > 0 && epoch_no % cadence == 0 {
                for &id in active.iter() {
                    let job = self.ledger.job_mut(id).expect("running job");
                    job.ckpt_iteration = job.iteration;
                }
            }
        }
        if !self.cfg.faults.is_empty() {
            let mut lost: Vec<(u64, u32)> = Vec::new();
            for ev in self.cfg.faults.events_at(epoch_no) {
                match ev.action {
                    FaultAction::Recover => self.pool.recover_node(ev.node),
                    FaultAction::Fail => self.pool.fail_node(ev.node, &mut lost),
                }
            }
            for &(id, cores) in &lost {
                lost_cores += cores;
                displaced.insert(id);
            }
            for &id in &displaced {
                let job = self.ledger.job_mut(id).expect("displaced job is running");
                job.pending_restart_iters = job.iteration - job.ckpt_iteration;
            }
        }
        if lost_cores != rec.lost_cores {
            return Err(corrupt(format!(
                "replay fault skew at t={t0}: log {} lost cores, state {lost_cores}",
                rec.lost_cores
            )));
        }

        // Elastic adaptation — the live epoch's stage 2c, re-derived
        // from the replayed iteration counters.
        for &id in active.iter() {
            let job = self.ledger.job_mut(id).expect("running job");
            if job.spec.elastic.is_empty() {
                continue;
            }
            let due = job
                .spec
                .elastic
                .iter()
                .take_while(|e| e.at_iteration <= job.iteration)
                .count() as u32;
            if due > job.elastic_applied {
                job.elastic_applied = due;
            }
        }

        let mut dirty: Vec<u64> = Vec::new();
        self.ledger.take_dirty_into(&mut dirty);
        if dirty.len() != rec.dirty_jobs {
            return Err(corrupt(format!(
                "replay dirty-set skew at t={t0}: log {}, state {}",
                rec.dirty_jobs,
                dirty.len()
            )));
        }
        let sync_ids: &[u64] = if self.cfg.selective_refits { &dirty } else { &active };
        let amortize = self.cfg.refit_amortization;
        let mut refits = 0usize;
        for &id in sync_ids {
            let job = self.ledger.job_mut(id).expect("synced job in ledger");
            if job.predictor.refresh_fit_deferrable(amortize) {
                refits += 1;
            }
        }
        if refits != rec.refits {
            return Err(corrupt(format!(
                "replay refit skew at t={t0}: log {}, state {refits}",
                rec.refits
            )));
        }

        // Degraded-mode tracking mirrors the live gain-view loop. The
        // flag is a pure function of the replayed predictor state, so the
        // transition counter re-derives exactly.
        for &id in active.iter() {
            let p = &self.ledger.job(id).expect("running job").predictor;
            let degraded = p.is_quarantined() || p.confidence() < 0.5;
            if degraded {
                if self.degraded_now.insert(id) {
                    self.degraded_transitions += 1;
                }
            } else {
                self.degraded_now.remove(&id);
            }
        }

        for (e, &id) in rec.entries.iter().zip(&active) {
            let loss = self.ledger.job(id).expect("running job").current_loss();
            if loss.to_bits() != e.loss.to_bits() {
                return Err(corrupt(format!(
                    "replay loss skew for job {id} at t={t0}: log {}, state {loss}",
                    e.loss
                )));
            }
        }

        // Apply the *logged* grants — the decision phase is what replay
        // elides — through the same placement-diff path as a live epoch,
        // capturing the pre-diff spans first when transitions are
        // charged (the reference placement for the voluntary-restart
        // mirror below).
        let charge_transitions = !self.cfg.transition.is_free();
        let prev_spans: Vec<u32> = if charge_transitions {
            active.iter().map(|&id| self.pool.rack_span(id) as u32).collect()
        } else {
            Vec::new()
        };
        let targets: Vec<(u64, u32)> =
            rec.entries.iter().map(|e| (e.job, e.cores)).collect();
        let delta = self.pool.apply_diff(&targets);
        if delta.cross_rack_moves != rec.cross_rack_moves {
            return Err(corrupt(format!(
                "replay placement skew at t={t0}: log {} cross-rack moves, state {}",
                rec.cross_rack_moves, delta.cross_rack_moves
            )));
        }
        for e in &rec.entries {
            let span = self.pool.rack_span(e.job) as u32;
            if span != e.rack_span {
                return Err(corrupt(format!(
                    "replay span skew for job {} at t={t0}: log {}, state {span}",
                    e.job, e.rack_span
                )));
            }
        }

        // Fault-repair accounting — the live epoch's park/unpark rule
        // driven by the logged grants — then cross-checked against the
        // logged counters.
        let mut replacements = 0u32;
        if !self.cfg.faults.is_empty() {
            let mut placement_failed = false;
            for e in &rec.entries {
                let prior = self.parked.get(&e.job).copied();
                let expired = prior.map_or(false, |(until, _)| epoch_no >= until);
                if !(displaced.contains(&e.job) || expired) {
                    continue;
                }
                if e.cores > 0 {
                    self.parked.remove(&e.job);
                    replacements += 1;
                } else {
                    placement_failed = true;
                    let backoff = prior.map_or(1, |(_, b)| (b * 2).min(8));
                    self.parked.insert(e.job, (epoch_no + backoff as u64, backoff));
                }
            }
            if placement_failed {
                self.failed_epochs += 1;
            }
        }
        if replacements != rec.replacements || self.failed_epochs != rec.failed_epochs {
            return Err(corrupt(format!(
                "replay repair skew at t={t0}: log ({}, {}), state ({replacements}, {})",
                rec.replacements, rec.failed_epochs, self.failed_epochs
            )));
        }

        // Voluntary-restart mirror of the live epoch's stage 6b, driven
        // by the logged grants and spans, then cross-checked against the
        // logged restart count.
        let mut voluntary_restarts = 0u32;
        if charge_transitions {
            for (i, e) in rec.entries.iter().enumerate() {
                let job = self.ledger.job_mut(e.job).expect("running job");
                let prev = job.cores;
                if prev == 0 {
                    continue;
                }
                let shrunk = e.cores < prev;
                let migrated = e.cores > 0 && e.rack_span > prev_spans[i];
                if !(shrunk || migrated) {
                    continue;
                }
                let debt = (job.iteration - job.ckpt_iteration)
                    + u64::from(
                        self.cfg.transition.warmup_iters(job.spec.cost.serial_secs),
                    );
                if debt > 0 {
                    job.pending_restart_iters = job.pending_restart_iters.max(debt);
                    voluntary_restarts += 1;
                }
            }
        }
        if voluntary_restarts != rec.voluntary_restarts {
            return Err(corrupt(format!(
                "replay transition skew at t={t0}: log {} voluntary restarts, \
                 state {voluntary_restarts}",
                rec.voluntary_restarts
            )));
        }

        // The logged record joins the trace verbatim (wall-clock nanos
        // included), so a recovered trace is the original trace.
        self.epochs.push(rec.clone());

        let mut completed_ids: Vec<u64> = Vec::new();
        for e in &rec.entries {
            let (id, span) = (e.job, e.rack_span);
            let job = self.ledger.job_mut(id).expect("running job");
            let slowdown = job.work_scaled(self.cfg.locality.slowdown(span as usize));
            job.max_rack_span = job.max_rack_span.max(span);
            let iterations = job.advance_with_locality(t0, window, e.cores, slowdown);
            let completed = job.state == JobState::Completed;
            if iterations > 0 {
                self.ledger.mark_dirty(id);
            }
            if completed {
                completed_ids.push(id);
                self.pool.release_all(id);
                self.ledger.retire(id);
                self.sched_ctx.forget(id);
                self.parked.remove(&id);
                self.degraded_now.remove(&id);
                if !self.shards.is_empty() {
                    let ns = self.shards.len() as u64;
                    self.shards[(id % ns) as usize].ctx.forget(id);
                }
            }
        }
        if completed_ids != ep.completed {
            return Err(corrupt(format!(
                "replay completion skew at t={t0}: log {:?}, state {completed_ids:?}",
                ep.completed
            )));
        }

        // Rebuild the scheduling contexts exactly as the live epoch left
        // them: `record()` keyed every request (0-core grants included),
        // then `forget()` removed the completions; the epoch counters
        // equal the epochs recorded. (`completed_ids` is ascending — it
        // was collected in `active` order.)
        let epoch_no = self.epochs.len() as u64;
        let survives = |id: u64| completed_ids.binary_search(&id).is_err();
        if self.shards.is_empty() {
            self.sched_ctx.restore_grants(
                rec.entries
                    .iter()
                    .filter(|e| survives(e.job))
                    .map(|e| (e.job, e.cores)),
                epoch_no,
            );
        } else {
            if ep.budgets.len() != self.shards.len() {
                return Err(corrupt(format!(
                    "replay budget skew at t={t0}: log {} shards, state {}",
                    ep.budgets.len(),
                    self.shards.len()
                )));
            }
            let ns = self.shards.len() as u64;
            for (si, shard) in self.shards.iter_mut().enumerate() {
                shard.ctx.restore_grants(
                    rec.entries
                        .iter()
                        .filter(|e| e.job % ns == si as u64 && survives(e.job))
                        .map(|e| (e.job, e.cores)),
                    epoch_no,
                );
                shard.budget = ep.budgets[si];
            }
        }

        self.time = t0 + window;
        self.push_notice();
        Ok(())
    }

    /// Append this boundary's [`EpochNotice`] to the retained history —
    /// called identically at the end of the live epoch and its replay,
    /// so the history is part of the bit-identical recovered state.
    fn push_notice(&mut self) {
        let (_, running, completed) = self.ledger.counts();
        self.notices.push(EpochNotice {
            epoch: self.epochs.len(),
            time: self.time,
            active: running,
            completed,
        });
    }

    /// The retained per-epoch notice history, oldest first — one entry
    /// per completed epoch, surviving crash recovery.
    pub fn epoch_notices(&self) -> &[EpochNotice] {
        &self.notices
    }

    /// Number of per-zone shards (0 when the coordinator is unsharded).
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Current per-shard core budgets, in shard id order (empty when
    /// unsharded). After any epoch these always sum to the cluster
    /// capacity — the broker's work-conservation invariant.
    pub fn shard_budgets(&self) -> Vec<u32> {
        self.shards.iter().map(|s| s.budget).collect()
    }

    /// Cumulative healthy→degraded gain-oracle transitions — the loud
    /// counter flagging that the scheduler stopped trusting some job's
    /// quality reports and fell back to the fair-share floor.
    pub fn degraded_transitions(&self) -> u64 {
        self.degraded_transitions
    }

    /// Jobs currently parked after a failed fault re-placement,
    /// ascending by id (empty on a fault-free run).
    pub fn parked_jobs(&self) -> Vec<u64> {
        self.parked.keys().copied().collect()
    }

    /// Cumulative count of epochs in which at least one fault-displaced
    /// job could not be re-placed (also recorded per epoch in the trace).
    pub fn failed_epochs(&self) -> u32 {
        self.failed_epochs
    }

    /// Live-thread counter of the worker pool, for lifecycle tests.
    #[cfg(test)]
    pub(super) fn worker_live_counter(
        &self,
    ) -> Option<std::sync::Arc<std::sync::atomic::AtomicUsize>> {
        self.workers.as_ref().map(|w| w.live_counter())
    }

    /// Resolved worker-thread count for the epoch pipeline's
    /// data-parallel stages (1 = serial reference path).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Submit a job (may arrive in the future). Job ids must be unique.
    ///
    /// On a durable coordinator the submission is WAL-logged *before* it
    /// takes effect (write-ahead), capturing the source's exact state —
    /// RNG cursor included — so recovery resubmits the same job
    /// bit-identically. Durable sources must implement
    /// [`LossSource::descriptor`].
    pub fn submit(&mut self, spec: JobSpec, source: Box<dyn LossSource>) {
        if let Some(d) = &mut self.durable {
            let desc = source
                .descriptor()
                .expect("durable coordinator needs a serializable loss source");
            d.wal
                .append(&WalRecord::Submit { spec: spec.clone(), source: desc })
                .expect("wal append (submit)");
        }
        self.ledger.submit(spec, source);
    }

    /// Cancel a job. Pending jobs never activate; running jobs release
    /// their cores and leave every hot set immediately. Returns `true`
    /// when the cancel took effect (`false` for unknown, completed or
    /// already-cancelled ids). Effective cancels are WAL-logged on
    /// durable coordinators; no-ops are not.
    pub fn cancel(&mut self, id: u64) -> bool {
        if !self.apply_cancel(id) {
            return false;
        }
        if let Some(d) = &mut self.durable {
            d.wal.append(&WalRecord::Cancel { id }).expect("wal append (cancel)");
        }
        true
    }

    /// The state change behind [`Coordinator::cancel`], shared with WAL
    /// replay (which must not re-log).
    fn apply_cancel(&mut self, id: u64) -> bool {
        match self.ledger.cancel(id) {
            None => false,
            Some(JobState::Pending) => true,
            Some(was_running) => {
                debug_assert_eq!(was_running, JobState::Running);
                self.pool.release_all(id);
                self.sched_ctx.forget(id);
                self.parked.remove(&id);
                self.degraded_now.remove(&id);
                if !self.shards.is_empty() {
                    let ns = self.shards.len() as u64;
                    self.shards[(id % ns) as usize].ctx.forget(id);
                }
                true
            }
        }
    }

    /// Arm a simulated kill for the crash-recovery harness: the next
    /// [`Coordinator::step_epoch`] aborts at `point` (see [`CrashPoint`])
    /// and the coordinator should then be discarded, as a killed process
    /// would be.
    pub fn set_crash_point(&mut self, point: CrashPoint) {
        self.crash_point = Some(point);
    }

    /// Number of epochs executed so far.
    pub fn epoch_count(&self) -> usize {
        self.epochs.len()
    }

    /// Whether this coordinator persists its state (built by
    /// [`Coordinator::with_persistence`] / [`Coordinator::recover_state`]).
    pub fn is_durable(&self) -> bool {
        self.durable.is_some()
    }

    /// Write a snapshot of the full mutable state right now (durable
    /// coordinators only; also done automatically every `snapshot_every`
    /// epochs). Atomic: a crash mid-write leaves the previous snapshot.
    pub fn snapshot_now(&mut self) -> io::Result<()> {
        let d = self.durable.as_ref().expect("snapshot_now on a non-durable coordinator");
        let view = SnapshotView {
            cfg: &self.cfg,
            policy: self.policy.name(),
            snapshot_every: d.snapshot_every as u64,
            time: self.time,
            wal_records: d.wal.records(),
            epochs: &self.epochs,
            ledger: &self.ledger,
            placements: self.pool.placements_snapshot(),
            ctx_epoch: self.sched_ctx.epoch(),
            ctx_grants: self.sched_ctx.grants(),
            shards: self
                .shards
                .iter()
                .map(|s| (s.budget, s.ctx.epoch(), s.ctx.grants()))
                .collect(),
            parked: self.parked.iter().map(|(&id, &(until, b))| (id, until, b)).collect(),
            degraded: self.degraded_now.iter().copied().collect(),
            degraded_transitions: self.degraded_transitions,
            notices: &self.notices,
        };
        view.write(&d.dir)
    }

    /// Current virtual time.
    pub fn time(&self) -> f64 {
        self.time
    }

    /// Policy name in use.
    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    /// Number of jobs in each state: (pending, running, completed).
    /// O(1) — maintained by the ledger, not recomputed by scanning.
    pub fn job_counts(&self) -> (usize, usize, usize) {
        self.ledger.counts()
    }

    /// Run one scheduling epoch.
    ///
    /// The hot loop touches pending jobs only when they arrive (ledger
    /// heap) and never revisits completed jobs; predictor refits visit
    /// only the ledger's dirty set (jobs with new loss samples); the
    /// allocator receives the persistent [`SchedContext`] so warm-start
    /// policies pay for what changed, not for cluster capacity. With
    /// `threads > 1` the refits, the gain-table build and (in sharded
    /// mode) the per-shard decisions run on the persistent worker pool
    /// (see the module docs for the determinism argument), and the large
    /// per-epoch buffers (id lists, placement targets, losses, the refit
    /// batch, the gain arena, the grant vector, the policy's heaps) come
    /// from reusable scratch pools, so steady-state epoch allocations are
    /// limited to what escapes into the trace plus a few small
    /// borrow-scoped vectors (the gain views and request lists).
    pub fn step_epoch(&mut self) {
        let t0 = self.time;
        let window = self.cfg.epoch_secs;
        let threads = self.threads;

        // 1. Activate arrivals — O(arrivals), driven by the arrival heap.
        // Activation observes each job's initial loss, which enters it
        // into the ledger's dirty set.
        self.ledger.activate_due(t0);

        // 2. The running set (completed jobs have already dropped out),
        // into a buffer reused across epochs.
        let mut active = std::mem::take(&mut self.scratch.active);
        self.ledger.running_ids_into(&mut active);

        // 2b. Fault boundary. On an empty `FaultSpec` this whole stage is
        // a no-op (no checkpoints, no pool mutation, all counters zero),
        // which is what keeps fault-free traces bitwise identical to
        // pre-fault builds. Otherwise: pin checkpoints on the cadence,
        // apply this epoch's scheduled recoveries then failures
        // (recover-before-fail is the `FaultSpec` event order), evict
        // placements on dead nodes, and charge each displaced job the
        // iterations it must re-do from its last checkpoint.
        let epoch_no = self.epochs.len() as u64;
        let mut lost_cores = 0u32;
        let mut displaced: BTreeSet<u64> = BTreeSet::new();
        let fault_epoch = !self.cfg.faults.is_empty()
            && !self.cfg.faults.events_at(epoch_no).is_empty();
        // Checkpoints are pinned whenever *any* restart source is live —
        // faults or a non-free transition model — so voluntary restarts
        // rewind to the same cadence faults do. With neither, the pin
        // loop never runs (the inertness contract).
        if !self.cfg.faults.is_empty() || !self.cfg.transition.is_free() {
            let cadence = self.cfg.checkpoint_epochs.max(1) as u64;
            if epoch_no > 0 && epoch_no % cadence == 0 {
                for &id in active.iter() {
                    let job = self.ledger.job_mut(id).expect("running job");
                    job.ckpt_iteration = job.iteration;
                }
            }
        }
        if !self.cfg.faults.is_empty() {
            let mut lost: Vec<(u64, u32)> = Vec::new();
            for ev in self.cfg.faults.events_at(epoch_no) {
                match ev.action {
                    FaultAction::Recover => self.pool.recover_node(ev.node),
                    FaultAction::Fail => self.pool.fail_node(ev.node, &mut lost),
                }
            }
            for &(id, cores) in &lost {
                lost_cores += cores;
                displaced.insert(id);
            }
            for &id in &displaced {
                let job = self.ledger.job_mut(id).expect("displaced job is running");
                job.pending_restart_iters = job.iteration - job.ckpt_iteration;
            }
        }

        // 2c. Elastic adaptation events: a job whose spec schedules
        // mid-training resizes (see `JobSpec::elastic`) acknowledges, at
        // the epoch boundary, every event whose trigger iteration has
        // been reached. The applied-prefix counter — not the raw
        // iteration — drives the derived cap/work-scale, so resizes take
        // effect at deterministic boundaries and replay bit-identically.
        // Jobs without elastic events skip the loop body entirely.
        for &id in active.iter() {
            let job = self.ledger.job_mut(id).expect("running job");
            if job.spec.elastic.is_empty() {
                continue;
            }
            let due = job
                .spec
                .elastic
                .iter()
                .take_while(|e| e.at_iteration <= job.iteration)
                .count() as u32;
            if due > job.elastic_applied {
                job.elastic_applied = due;
            }
        }

        // 3. Predictor sync: refit only the jobs that received samples
        // since the last sync — O(jobs-that-changed), not O(active). The
        // refit-all sweep survives as a reference path (`selective_refits:
        // false`); it visits every active job but `refresh_fit` no-ops on
        // clean predictors, so the two paths produce identical fits (the
        // quality-fidelity equivalence property pins this down).
        let refit_start = Instant::now();
        let mut dirty = std::mem::take(&mut self.scratch.dirty);
        self.ledger.take_dirty_into(&mut dirty);
        let dirty_jobs = dirty.len();
        let sync_ids: &[u64] = if self.cfg.selective_refits { &dirty } else { &active };
        let amortize = self.cfg.refit_amortization;
        let mut refits = 0usize;
        if threads <= 1 || sync_ids.len() < 2 {
            // Serial reference path.
            for &id in sync_ids {
                let job = self.ledger.job_mut(id).expect("synced job in ledger");
                if job.predictor.refresh_fit_deferrable(amortize) {
                    refits += 1;
                }
            }
        } else {
            // Sharded refits. Each dirty predictor is *moved* out of its
            // ledger row (plain `Send + Sync` data — the job row itself,
            // which holds the non-`Sync` loss source, stays put), refit by
            // exactly one worker, and returned to its row in the stable
            // ascending-id order of `sync_ids`. Every output has a
            // preassigned slot and the only cross-shard aggregate is an
            // integer sum, so the merged state is bit-identical at any
            // thread count.
            let mut batch = std::mem::take(&mut self.scratch.refit_batch);
            debug_assert!(batch.is_empty());
            for &id in sync_ids {
                let job = self.ledger.job_mut(id).expect("synced job in ledger");
                let placeholder = OnlinePredictor::new(job.spec.kind);
                batch.push((id, std::mem::replace(&mut job.predictor, placeholder)));
            }
            let len = batch.len();
            let chunk = (len / threads + usize::from(len % threads != 0)).max(1);
            let mut counts = std::mem::take(&mut self.scratch.refit_counts);
            counts.clear();
            counts.resize(batch.chunks(chunk).len(), 0);
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = batch
                .chunks_mut(chunk)
                .zip(counts.iter_mut())
                .map(|(shard, slot)| {
                    Box::new(move || {
                        let mut done = 0usize;
                        for (_, predictor) in shard.iter_mut() {
                            if predictor.refresh_fit_deferrable(amortize) {
                                done += 1;
                            }
                        }
                        *slot = done;
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            self.workers.as_ref().expect("threads > 1 implies a worker pool").run(tasks);
            refits = counts.iter().sum();
            self.scratch.refit_counts = counts;
            for (id, predictor) in batch.drain(..) {
                self.ledger.job_mut(id).expect("synced job in ledger").predictor = predictor;
            }
            self.scratch.refit_batch = batch;
        }
        let refit_nanos = refit_start.elapsed().as_nanos() as u64;

        // Simulated mid-epoch kill (crash harness): nothing of this epoch
        // has reached disk, so recovery lands on the previous boundary.
        // The in-memory mutations above — refreshed fits, the drained
        // dirty set — die with the process image, as they would under a
        // real `kill -9` here.
        if self.crash_point == Some(CrashPoint::AfterRefit) {
            self.scratch.active = active;
            self.scratch.dirty = dirty;
            return;
        }

        // Allocate over what actually survives: with dead nodes the
        // schedulable capacity shrinks to the pool's live cores (equal to
        // the static cluster capacity on a fault-free run, so this line
        // is inert there).
        let capacity = self.pool.surviving_capacity();
        let gain_nanos;
        let sched_nanos;
        let mut grant = std::mem::take(&mut self.scratch.grant);
        let mut targets = std::mem::take(&mut self.scratch.targets);
        targets.clear();
        let mut losses = std::mem::take(&mut self.scratch.losses);
        losses.clear();
        let mut entries: Vec<EpochEntry>;
        {
            // One ledger lookup per job: the gain views for the allocator
            // and the epoch-start losses for the record below. Each view
            // carries the locality slowdown of the placement the job
            // enters the epoch with (its current rack span), so predicted
            // gains price fragmentation the same way execution pays it.
            let mut gains: Vec<JobGain<'_>> = Vec::with_capacity(active.len());
            let fair_share =
                (capacity / (active.len().max(1) as u32)).max(1);
            // Planner-side transition pricing is live only when both the
            // config asks for it and the model is non-free; otherwise
            // every penalty stays 0.0 and net_gain ≡ gain bit for bit.
            let price = self.cfg.price_transitions && !self.cfg.transition.is_free();
            for &id in active.iter() {
                let job = self.ledger.job(id).expect("running job");
                let slowdown = job
                    .work_scaled(self.cfg.locality.slowdown(self.pool.rack_span(id)));
                // Degraded-mode gate: a quarantined predictor (run of
                // rejected loss reports) or collapsed sample confidence
                // means the fitted curve is untrustworthy. Track
                // healthy→degraded transitions loudly; the flag itself is
                // pure predictor state, so replay recomputes it exactly.
                let degraded =
                    job.predictor.is_quarantined() || job.predictor.confidence() < 0.5;
                if degraded {
                    if self.degraded_now.insert(id) {
                        self.degraded_transitions += 1;
                    }
                } else {
                    self.degraded_now.remove(&id);
                }
                let mut g =
                    JobGain::new(job, window, self.cfg.cold_start_optimism, slowdown);
                let parked_now =
                    self.parked.get(&id).map_or(false, |&(until, _)| epoch_no < until);
                if parked_now {
                    // Parked after a failed re-placement: request nothing
                    // until the backoff expires.
                    g.cap = 0;
                } else if degraded {
                    g.degraded = true;
                    g.fair_share = fair_share;
                    g.cap = g.cap.min(fair_share);
                } else if price && job.cores > 0 {
                    // Materialize this job's transition penalty once per
                    // epoch: the quality it would forfeit if shrunk —
                    // the iterations since its last checkpoint (rewound)
                    // plus restore/warmup plus the checkpoint write,
                    // pushed through the same predicted-reduction curve
                    // `gain` uses (iterations at face value during cold
                    // start, exactly like the `dk` fallback). Degraded
                    // jobs keep penalty 0 — their epsilon-scale floor
                    // would be swamped, and they are already clamped to
                    // the fair share.
                    let iters = (job.iteration - job.ckpt_iteration) as f64
                        + f64::from(
                            self.cfg.transition.warmup_iters(job.spec.cost.serial_secs),
                        )
                        + self.cfg.transition.checkpoint_write_iters;
                    if iters > 0.0 {
                        g.penalty = if self.cfg.cold_start_optimism
                            && job.predictor.history().len() < 3
                        {
                            iters
                        } else {
                            job.predictor.predicted_normalized_reduction(iters)
                        };
                    }
                }
                gains.push(g);
                losses.push(job.current_loss());
            }

            if self.shards.is_empty() {
                // 4. Materialize the gain table (threads > 1, and only
                // for policies that actually read them — fair/FIFO/static
                // never consult gains, so building them a table would be
                // pure waste): every job's gain curve evaluated once into
                // the context's flat arena, split into contiguous row
                // ranges across the persistent worker pool, so the
                // allocator's innermost loops become O(1) lookups. Timed
                // separately — the epoch's third cost split next to
                // refits and allocation. The fill goes through the shared
                // `GainTable::fill_shard` (one definition of the row
                // layout) over the same `JobGain` views the serial path
                // hands the allocator, so table entries are bit-identical
                // to oracle calls.
                {
                    let table = self.sched_ctx.gain_table_mut();
                    if threads > 1 && self.policy.wants_gain_table() {
                        let gain_start = Instant::now();
                        table.reset(
                            active.iter().zip(&gains).map(|(&id, g)| (id, g.cap(), g.prev)),
                        );
                        let gains_ref: &[JobGain<'_>] = &gains;
                        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = table
                            .shards_mut(threads)
                            .into_iter()
                            .map(|(rows, slice)| {
                                Box::new(move || {
                                    GainTable::fill_shard(
                                        rows,
                                        slice,
                                        |r| gains_ref[r].cap() as usize,
                                        |r, c| gains_ref[r].net_gain(gains_ref[r].prev, c),
                                    )
                                }) as Box<dyn FnOnce() + Send + '_>
                            })
                            .collect();
                        self.workers
                            .as_ref()
                            .expect("threads > 1 implies a worker pool")
                            .run(tasks);
                        table.mark_ready();
                        gain_nanos = gain_start.elapsed().as_nanos() as u64;
                    } else {
                        table.invalidate();
                        gain_nanos = 0;
                    }
                }

                let requests: Vec<JobRequest<'_>> = active
                    .iter()
                    .zip(&gains)
                    .map(|(&id, g)| JobRequest {
                        id,
                        max_cores: g.cap(),
                        prev_cores: g.prev,
                        gain: g,
                    })
                    .collect();

                // 5. Allocate (this is the decision Fig 6 times), writing
                // into the persistent grant buffer — steady-state epochs
                // reuse it instead of allocating a grant per decision.
                // The context carries the previous grant for the
                // warm-start path and the freshly built gain table.
                let start = Instant::now();
                self.policy.allocate_ctx_into(&self.sched_ctx, &requests, capacity, &mut grant);
                sched_nanos = start.elapsed().as_nanos() as u64;

                // Persist this epoch's grant for the next warm start
                // (which also retires the table — its rows describe this
                // epoch), and republish the policy's decision-cost model
                // so context observers (benchmarks, traces) can read it.
                self.sched_ctx.record(&requests, &grant);
                if let Some(stats) = self.policy.decision_stats() {
                    self.sched_ctx.record_stats(stats);
                }
            } else {
                // 4'. Sharded epoch (see the module docs): partition the
                // active positions by `id mod zones` (stable, ascending
                // within each shard), materialize per-shard gain tables
                // in parallel, let the broker re-split the core budgets
                // on its cadence, then run every shard's decision
                // concurrently against its own budget and merge the
                // grants in shard-index order.
                let ns = self.shards.len() as u64;
                for shard in &mut self.shards {
                    shard.idx.clear();
                }
                for (i, &id) in active.iter().enumerate() {
                    self.shards[(id % ns) as usize].idx.push(i);
                }
                let gains_ref: &[JobGain<'_>] = &gains;
                let active_ref: &[u64] = &active;

                // Phase A — per-shard gain tables. Each shard's table is
                // reset and filled by exactly one task over that shard's
                // rows (same `JobGain` views, so table ≡ oracle bitwise).
                let build_tables = threads > 1
                    && self.shards.first().map(|s| s.policy.wants_gain_table()).unwrap_or(false);
                let gain_start = Instant::now();
                if build_tables {
                    let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = self
                        .shards
                        .iter_mut()
                        .map(|shard| {
                            Box::new(move || {
                                let Shard { ctx, idx, .. } = shard;
                                let table = ctx.gain_table_mut();
                                table.reset(idx.iter().map(|&i| {
                                    (active_ref[i], gains_ref[i].cap(), gains_ref[i].prev)
                                }));
                                for (rows, slice) in table.shards_mut(1) {
                                    GainTable::fill_shard(
                                        rows,
                                        slice,
                                        |r| gains_ref[idx[r]].cap() as usize,
                                        |r, c| {
                                            let g = &gains_ref[idx[r]];
                                            g.net_gain(g.prev, c)
                                        },
                                    );
                                }
                                table.mark_ready();
                            }) as Box<dyn FnOnce() + Send + '_>
                        })
                        .collect();
                    self.workers
                        .as_ref()
                        .expect("threads > 1 implies a worker pool")
                        .run(tasks);
                } else {
                    for shard in &mut self.shards {
                        shard.ctx.gain_table_mut().invalidate();
                    }
                }

                // Broker — every `broker_epochs` epochs (always the
                // first), re-split capacity across the shard budgets
                // from each shard's aggregate demand curve: descending
                // first-core gains and upgrade marginals, read from the
                // fresh tables when built, the oracles otherwise (the
                // same bits either way). Rides the gain split, not the
                // decision split — it digests gain curves, and the sched
                // percentiles must keep measuring the allocator itself.
                // A fault epoch forces a rebalance regardless of cadence:
                // budgets fixed against the old capacity would let the
                // shards collectively oversubscribe the surviving cores
                // (or strand the recovered ones). `fault_epoch` is always
                // false on an empty spec, so the cadence is untouched on
                // fault-free runs.
                if fault_epoch || self.epochs.len() % self.cfg.broker_epochs.max(1) == 0 {
                    let mut demand: Vec<ShardDemand> = Vec::with_capacity(self.shards.len());
                    for shard in &self.shards {
                        let mut d = ShardDemand::default();
                        let table = shard.ctx.gain_table();
                        for (row, &i) in shard.idx.iter().enumerate() {
                            let cap = gains_ref[i].cap();
                            if cap == 0 {
                                continue;
                            }
                            d.eligible_jobs += 1;
                            let g = |c: u32| match table {
                                Some(t) => t.gain(row, c),
                                None => gains_ref[i].net_gain(gains_ref[i].prev, c),
                            };
                            let mut prev = g(1);
                            d.first_core.push(prev);
                            for k in 2..=cap {
                                let gk = g(k);
                                d.upgrades.push(gk - prev);
                                prev = gk;
                            }
                        }
                        d.finish(capacity as usize);
                        demand.push(d);
                    }
                    let budgets = rebalance_budgets(capacity, &demand);
                    for (shard, b) in self.shards.iter_mut().zip(budgets) {
                        shard.budget = b;
                    }
                }
                gain_nanos = gain_start.elapsed().as_nanos() as u64;

                // Phase B — every shard's decision, concurrently. Each
                // task touches only its own shard's policy/context/grant
                // (plus shared `Sync` gain views), builds its request
                // view locally, and records the grant for the shard's
                // next warm start — O(shard) work per task.
                let start = Instant::now();
                let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = self
                    .shards
                    .iter_mut()
                    .map(|shard| {
                        Box::new(move || {
                            let Shard { policy, ctx, budget, grant, idx } = shard;
                            let requests: Vec<JobRequest<'_>> = idx
                                .iter()
                                .map(|&i| JobRequest {
                                    id: active_ref[i],
                                    max_cores: gains_ref[i].cap(),
                                    prev_cores: gains_ref[i].prev,
                                    gain: &gains_ref[i],
                                })
                                .collect();
                            policy.allocate_ctx_into(ctx, &requests, *budget, grant);
                            ctx.record(&requests, grant);
                            if let Some(stats) = policy.decision_stats() {
                                ctx.record_stats(stats);
                            }
                        }) as Box<dyn FnOnce() + Send + '_>
                    })
                    .collect();
                match &self.workers {
                    Some(pool) => pool.run(tasks),
                    None => tasks.into_iter().for_each(|t| t()),
                }
                sched_nanos = start.elapsed().as_nanos() as u64;

                // Merge: scatter the shard grants back through each
                // shard's fixed index list — deterministic regardless of
                // which worker ran which shard.
                grant.cores.clear();
                grant.cores.resize(active.len(), 0);
                for shard in &self.shards {
                    for (pos, &i) in shard.idx.iter().enumerate() {
                        grant.cores[i] = shard.grant.cores[pos];
                    }
                }
                if let Some(stats) =
                    self.shards.first().and_then(|s| s.policy.decision_stats())
                {
                    self.sched_ctx.record_stats(stats);
                }
            }

            targets.extend(active.iter().zip(&grant.cores).map(|(&id, &cores)| (id, cores)));
            // Epoch record (losses at epoch start, before jobs advance;
            // rack spans are stamped after the placement diff below).
            entries = active
                .iter()
                .zip(&losses)
                .zip(&grant.cores)
                .map(|((&id, &loss), &cores)| EpochEntry { job: id, cores, loss, rack_span: 0 })
                .collect();
        }

        // 5b. Fault-repair accounting (inert when no faults are
        // configured): a job displaced this epoch, or whose park just
        // expired, either regained cores — a replacement — or parks with
        // doubled backoff. An epoch where at least one such job came away
        // empty bumps the cumulative failed-epochs counter.
        let mut replacements = 0u32;
        if !self.cfg.faults.is_empty() {
            let mut placement_failed = false;
            for (&id, &granted) in active.iter().zip(&grant.cores) {
                let prior = self.parked.get(&id).copied();
                let expired = prior.map_or(false, |(until, _)| epoch_no >= until);
                if !(displaced.contains(&id) || expired) {
                    continue;
                }
                if granted > 0 {
                    self.parked.remove(&id);
                    replacements += 1;
                } else {
                    placement_failed = true;
                    let backoff = prior.map_or(1, |(_, b)| (b * 2).min(8));
                    self.parked.insert(id, (epoch_no + backoff as u64, backoff));
                }
            }
            if placement_failed {
                self.failed_epochs += 1;
            }
        }

        // 6. Apply only the placement deltas (shrink first, then grow) —
        // the locality-aware grow prefers racks each job already
        // occupies, and the delta accounts the cores that had to cross
        // racks anyway. The post-placement spans are computed once into
        // reusable scratch and shared by the trace entries and the
        // advance loop below. When the transition model is non-free the
        // pre-diff spans are captured first: they are the reference
        // placement for the voluntary-restart stage (6b).
        let charge_transitions = !self.cfg.transition.is_free();
        let prev_spans: Vec<u32> = if charge_transitions {
            active.iter().map(|&id| self.pool.rack_span(id) as u32).collect()
        } else {
            Vec::new()
        };
        let placement_delta = self.pool.apply_diff(&targets);
        let mut spans = std::mem::take(&mut self.scratch.spans);
        spans.clear();
        spans.extend(active.iter().map(|&id| self.pool.rack_span(id) as u32));
        for (e, &span) in entries.iter_mut().zip(&spans) {
            e.rack_span = span;
        }

        // 6b. Voluntary-restart accounting: with a non-free transition
        // model the simulator *charges* every disruptive reallocation,
        // whether or not the planner priced it (`price_transitions`
        // only steers the gain view — the physics are unconditional, so
        // the aggressive arm of `exp::elastic` pays for what it
        // ignores). A job shrunk below the cores it held entering the
        // epoch (a pause counts), or granted cores across a wider rack
        // span than before, rewinds to its last checkpoint and burns
        // restore-plus-warmup iterations on the simulated clock via the
        // same `pending_restart_iters` debt the fault path uses. Debts
        // max-merge so a voluntary restart never erases a larger
        // fault-induced one. With the default free model the stage is
        // skipped entirely — bitwise inert.
        let mut voluntary_restarts = 0u32;
        if charge_transitions {
            for (i, (&id, &granted)) in active.iter().zip(&grant.cores).enumerate() {
                let job = self.ledger.job_mut(id).expect("running job");
                let prev = job.cores;
                if prev == 0 {
                    continue;
                }
                let shrunk = granted < prev;
                let migrated = granted > 0 && spans[i] > prev_spans[i];
                if !(shrunk || migrated) {
                    continue;
                }
                let debt = (job.iteration - job.ckpt_iteration)
                    + u64::from(
                        self.cfg.transition.warmup_iters(job.spec.cost.serial_secs),
                    );
                if debt > 0 {
                    job.pending_restart_iters = job.pending_restart_iters.max(debt);
                    voluntary_restarts += 1;
                }
            }
        }

        // 7. Record the epoch before advancing.
        self.epochs.push(EpochRecord {
            time: t0,
            sched_nanos,
            refit_nanos,
            gain_nanos,
            refits,
            dirty_jobs,
            active_jobs: active.len(),
            cross_rack_moves: placement_delta.cross_rack_moves,
            lost_cores,
            replacements,
            failed_epochs: self.failed_epochs,
            voluntary_restarts,
            entries,
        });

        // 8. Advance jobs through the window — on the iteration clock of
        // the placement they actually received (fragmented placements run
        // slower); jobs that completed iterations re-enter the dirty set
        // for the next sync, while completed jobs leave the running set,
        // the dirty set, the node pool and the scheduling context for
        // good.
        let log_epoch = self.durable.is_some();
        let mut completed_ids: Vec<u64> = Vec::new();
        for ((&id, &cores), &span) in active.iter().zip(&grant.cores).zip(&spans) {
            let job = self.ledger.job_mut(id).expect("running job");
            let slowdown = job.work_scaled(self.cfg.locality.slowdown(span as usize));
            job.max_rack_span = job.max_rack_span.max(span);
            let iterations = job.advance_with_locality(t0, window, cores, slowdown);
            let completed = job.state == JobState::Completed;
            if iterations > 0 {
                self.ledger.mark_dirty(id);
            }
            if completed {
                if log_epoch {
                    completed_ids.push(id);
                }
                self.pool.release_all(id);
                self.ledger.retire(id);
                self.sched_ctx.forget(id);
                self.parked.remove(&id);
                self.degraded_now.remove(&id);
                if !self.shards.is_empty() {
                    let ns = self.shards.len() as u64;
                    self.shards[(id % ns) as usize].ctx.forget(id);
                }
            }
        }

        // Return the reusable buffers to the scratch pool.
        self.scratch.active = active;
        self.scratch.dirty = dirty;
        self.scratch.targets = targets;
        self.scratch.losses = losses;
        self.scratch.spans = spans;
        self.scratch.grant = grant;

        self.time = t0 + window;
        self.push_notice();

        // Simulated kill after full in-memory execution but before the
        // epoch record reached the WAL — the other half of the durability
        // window. The epoch never becomes durable; recovery replays to
        // the previous boundary.
        if self.crash_point == Some(CrashPoint::BeforeWalAppend) {
            return;
        }
        if log_epoch {
            self.append_epoch_wal(completed_ids).expect("wal append (epoch)");
        }
    }

    /// Make the epoch just executed durable: append its WAL record (the
    /// trace record plus completions, post-broker shard budgets and the
    /// decision-cost sample counters), then snapshot if the cadence says
    /// so. Called as the last act of [`Coordinator::step_epoch`] — a
    /// crash anywhere before this leaves the previous boundary durable.
    fn append_epoch_wal(&mut self, completed: Vec<u64>) -> io::Result<()> {
        let record =
            self.epochs.last().expect("epoch record pushed before WAL append").clone();
        let (warm_samples, scratch_samples) = self
            .sched_ctx
            .decision_stats()
            .map(|s| (s.warm_samples(), s.scratch_samples()))
            .unwrap_or((0, 0));
        let ep = WalEpoch {
            record,
            completed,
            budgets: self.shards.iter().map(|s| s.budget).collect(),
            warm_samples,
            scratch_samples,
        };
        let d = self.durable.as_mut().expect("durable state");
        d.wal.append(&WalRecord::Epoch(Box::new(ep)))?;
        if self.epochs.len() % self.durable.as_ref().unwrap().snapshot_every == 0 {
            self.snapshot_now()?;
            // The snapshot just written is self-contained, so every WAL
            // frame it covers is dead weight: compact the log down to
            // its genesis record (atomic tmp + rename) and snapshot once
            // more so the recorded replay high-water mark matches the
            // compacted file. A crash between the rename and the second
            // snapshot leaves a mark above the file's frame count —
            // exactly the stale-snapshot case recovery rewrites.
            let d = self.durable.as_mut().expect("durable state");
            d.wal = compact_wal(&d.dir.join(WAL_FILE))?;
            self.snapshot_now()?;
        }
        Ok(())
    }

    /// Run epochs until virtual time reaches `t_end`.
    pub fn run_until(&mut self, t_end: f64) {
        while self.time < t_end {
            self.step_epoch();
        }
    }

    /// Run until every submitted job completes (with an epoch safety cap).
    pub fn run_to_completion(&mut self, max_epochs: usize) {
        for _ in 0..max_epochs {
            let (pending, running, _) = self.job_counts();
            if pending == 0 && running == 0 {
                return;
            }
            self.step_epoch();
        }
    }

    /// Immutable view of the job ledger.
    pub fn ledger(&self) -> &JobLedger {
        &self.ledger
    }

    /// The most recent epoch's record, if any epoch has run (the full
    /// history is extracted by [`Coordinator::into_trace`]).
    pub fn last_epoch(&self) -> Option<&EpochRecord> {
        self.epochs.last()
    }

    /// The persistent scheduling context (previous grant + the policy's
    /// published decision-cost statistics).
    pub fn sched_context(&self) -> &SchedContext {
        &self.sched_ctx
    }

    /// Node pool (placement state).
    pub fn pool(&self) -> &NodePool {
        &self.pool
    }

    /// Extract the full trace (consumes the coordinator).
    pub fn into_trace(self) -> Trace {
        let jobs = self
            .ledger
            .into_entries()
            .map(|(id, entry)| {
                let j = entry.job;
                JobTrace {
                    id,
                    name: j.spec.name,
                    arrival: j.spec.arrival,
                    max_cores: j.spec.max_cores,
                    max_rack_span: j.max_rack_span,
                    activated: entry.activated_at,
                    completion: j.completion_time,
                    floor: j.source.known_floor(),
                    initial_loss: j.initial_loss,
                    samples: j.loss_trace,
                }
            })
            .collect();
        Trace { epochs: self.epochs, jobs }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::CostModel;
    use crate::coordinator::source::SyntheticSource;
    use crate::predictor::{CurveKind, CurveModel};
    use crate::sched::{FairPolicy, SlaqPolicy};
    use crate::util::rng::Rng;

    fn mk_spec(id: u64, arrival: f64, kind: CurveKind) -> JobSpec {
        JobSpec {
            id,
            name: format!("job-{id}"),
            kind,
            cost: CostModel::new(0.05, 4.0),
            max_cores: 32,
            arrival,
            target_fraction: 0.95,
            max_iterations: 5_000,
            target_hint: None,
            elastic: Vec::new(),
        }
    }

    fn exp_source(seed: u64, mu: f64) -> Box<dyn LossSource> {
        Box::new(SyntheticSource::new(
            CurveModel::Exponential { m: 4.0, mu, c: 1.0 },
            0.0,
            Rng::new(seed),
        ))
    }

    fn small_cluster() -> CoordinatorConfig {
        CoordinatorConfig {
            cluster: ClusterSpec { nodes: 2, cores_per_node: 16 },
            epoch_secs: 2.0,
            ..Default::default()
        }
    }

    #[test]
    fn single_job_runs_to_completion() {
        let mut c = Coordinator::new(small_cluster(), Box::new(SlaqPolicy::new()));
        c.submit(mk_spec(0, 0.0, CurveKind::Exponential), exp_source(1, 0.85));
        c.run_to_completion(1000);
        let (p, r, done) = c.job_counts();
        assert_eq!((p, r, done), (0, 0, 1));
        let trace = c.into_trace();
        assert_eq!(trace.jobs.len(), 1);
        assert!(trace.jobs[0].completion.is_some());
        assert!(!trace.epochs.is_empty());
    }

    #[test]
    fn future_arrivals_wait() {
        let mut c = Coordinator::new(small_cluster(), Box::new(SlaqPolicy::new()));
        c.submit(mk_spec(0, 100.0, CurveKind::Exponential), exp_source(1, 0.85));
        c.run_until(10.0);
        let (p, r, done) = c.job_counts();
        assert_eq!((p, r, done), (1, 0, 0));
    }

    #[test]
    fn completed_jobs_release_cores() {
        let mut c = Coordinator::new(small_cluster(), Box::new(SlaqPolicy::new()));
        c.submit(mk_spec(0, 0.0, CurveKind::Exponential), exp_source(1, 0.5));
        c.run_to_completion(1000);
        assert_eq!(c.pool().free_cores(), 32);
        c.pool().check_invariants();
    }

    #[test]
    fn epoch_allocations_respect_capacity() {
        let mut c = Coordinator::new(small_cluster(), Box::new(SlaqPolicy::new()));
        for id in 0..6 {
            c.submit(
                mk_spec(id, 0.0, CurveKind::Exponential),
                exp_source(id + 1, 0.8 + 0.02 * id as f64),
            );
        }
        c.run_until(20.0);
        c.pool().check_invariants();
        let trace = c.into_trace();
        for e in &trace.epochs {
            let total: u32 = e.entries.iter().map(|en| en.cores).sum();
            assert!(total <= 32, "epoch at {} over capacity: {total}", e.time);
        }
    }

    #[test]
    fn fair_policy_splits_evenly() {
        let mut c = Coordinator::new(small_cluster(), Box::new(FairPolicy::new()));
        for id in 0..4 {
            c.submit(mk_spec(id, 0.0, CurveKind::Exponential), exp_source(id + 1, 0.9));
        }
        c.step_epoch();
        let trace = c.into_trace();
        let e = &trace.epochs[0];
        for en in &e.entries {
            assert_eq!(en.cores, 8, "fair share of 32 over 4 jobs");
        }
    }

    #[test]
    fn ledger_counts_track_the_epoch_loop() {
        let mut c = Coordinator::new(small_cluster(), Box::new(SlaqPolicy::new()));
        c.submit(mk_spec(0, 0.0, CurveKind::Exponential), exp_source(1, 0.5));
        c.submit(mk_spec(1, 1000.0, CurveKind::Exponential), exp_source(2, 0.5));
        assert_eq!(c.job_counts(), (2, 0, 0));
        c.step_epoch();
        assert_eq!(c.job_counts().0, 1, "future arrival must stay pending");
        c.run_until(100.0);
        let (p, r, done) = c.job_counts();
        assert_eq!((p, done), (1, 1), "fast job completes, future stays pending");
        assert_eq!(r, 0);
        assert_eq!(c.ledger().len(), 2);
    }

    #[test]
    fn epoch_loop_publishes_decision_stats() {
        let mut c = Coordinator::new(small_cluster(), Box::new(SlaqPolicy::new()));
        for id in 0..3 {
            c.submit(mk_spec(id, 0.0, CurveKind::Exponential), exp_source(id + 1, 0.9));
        }
        // Epoch 1 allocates from an empty context; epoch 2 exercises the
        // timed warm-or-scratch decision, which feeds the published model.
        c.step_epoch();
        c.step_epoch();
        let stats = c.sched_context().decision_stats().expect("slaq publishes its model");
        assert!(
            stats.warm_samples() + stats.scratch_samples() >= 1,
            "second epoch must feed the decision-cost model"
        );
        assert!(c.last_epoch().is_some());
        assert_eq!(c.last_epoch().unwrap().active_jobs, 3);
    }

    #[test]
    fn selective_sync_skips_jobs_without_new_samples() {
        let mut c = Coordinator::new(small_cluster(), Box::new(SlaqPolicy::new()));
        // Fast job: completes several iterations every epoch.
        c.submit(mk_spec(0, 0.0, CurveKind::Exponential), exp_source(1, 0.9));
        // Slow job: a single iteration takes ~10 epochs at its 1-core cap,
        // so most epochs bring it no new samples.
        let mut slow = mk_spec(1, 0.0, CurveKind::Exponential);
        slow.cost = CostModel::new(0.5, 20.0);
        slow.max_cores = 1;
        c.submit(slow, exp_source(2, 0.9));
        for _ in 0..6 {
            c.step_epoch();
        }
        let trace = c.into_trace();
        for e in &trace.epochs {
            assert!(
                e.refits <= e.dirty_jobs && e.dirty_jobs <= e.active_jobs,
                "refit accounting out of order at t={}: {} / {} / {}",
                e.time,
                e.refits,
                e.dirty_jobs,
                e.active_jobs
            );
        }
        assert_eq!(trace.epochs[0].dirty_jobs, 2, "activation marks both jobs dirty");
        assert!(
            trace
                .epochs
                .iter()
                .skip(1)
                .any(|e| e.active_jobs == 2 && e.dirty_jobs < 2),
            "the sample-less job must drop out of the refit bill"
        );
    }

    #[test]
    fn quality_fidelity_selective_equals_refit_all_on_random_churn() {
        // The tentpole's safety net: the dirty-set sync and the historical
        // sweep over every active job must be *indistinguishable* — same
        // per-epoch allocations, same loss trajectories, same completions
        // — on arbitrary churn traces. Uses the deterministic SLAQ variant
        // so both runs take identical decision paths.
        use crate::testkit::{forall, sim};
        forall("selective ≡ refit-all coordinators", 6, |g| {
            let templates = sim::random_churn_templates(g, 14, 40.0);
            let src_seed = g.u64();
            let run = |selective: bool| {
                let cfg = CoordinatorConfig {
                    cluster: ClusterSpec { nodes: 3, cores_per_node: 8 },
                    epoch_secs: 2.0,
                    cold_start_optimism: true,
                    selective_refits: selective,
                    refit_amortization: false,
                    threads: 1,
                    ..Default::default()
                };
                let mut c = Coordinator::new(cfg, Box::new(SlaqPolicy::deterministic()));
                sim::submit_templates(&mut c, &templates, src_seed);
                c.run_until(80.0);
                c.into_trace()
            };
            let sel = run(true);
            let all = run(false);
            assert_eq!(sel.epochs.len(), all.epochs.len());
            for (a, b) in sel.epochs.iter().zip(&all.epochs) {
                assert_eq!(a.active_jobs, b.active_jobs, "active sets diverged at t={}", a.time);
                assert_eq!(a.entries.len(), b.entries.len());
                for (x, y) in a.entries.iter().zip(&b.entries) {
                    assert_eq!(x.job, y.job);
                    assert_eq!(x.cores, y.cores, "allocations diverged at t={}", a.time);
                    assert_eq!(x.loss, y.loss, "losses diverged at t={}", a.time);
                }
            }
            assert_eq!(sel.jobs.len(), all.jobs.len());
            for (a, b) in sel.jobs.iter().zip(&all.jobs) {
                assert_eq!(a.id, b.id);
                assert_eq!(a.completion, b.completion, "completion diverged for job {}", a.id);
                assert_eq!(
                    a.samples.last().map(|s| s.2),
                    b.samples.last().map(|s| s.2),
                    "final losses diverged for job {}",
                    a.id
                );
            }
        });
    }

    #[test]
    fn parallel_epoch_pipeline_is_bit_identical_to_serial() {
        // The tentpole's safety net: sharding the refits and materializing
        // the gain tables (threads > 1) must be *indistinguishable* from
        // the serial reference path (threads = 1, direct oracle calls) —
        // same per-epoch allocations, same loss trajectories, same
        // completions — on arbitrary churn traces, at every thread count.
        // Uses the deterministic SLAQ variant so decision paths never
        // depend on wall clock. This doubles as the coordinator-level
        // "gain-table allocation ≡ direct-oracle allocation" property:
        // the serial run evaluates oracles inside the allocator, the
        // parallel runs allocate purely from the materialized tables.
        use crate::testkit::{forall, sim};
        forall("threads=1 ≡ threads=N coordinators", 4, |g| {
            let templates = sim::random_churn_templates(g, 12, 30.0);
            let src_seed = g.u64();
            let run = |threads: usize| {
                let cfg = CoordinatorConfig {
                    cluster: ClusterSpec { nodes: 3, cores_per_node: 8 },
                    epoch_secs: 2.0,
                    cold_start_optimism: true,
                    selective_refits: true,
                    refit_amortization: false,
                    threads,
                    ..Default::default()
                };
                let mut c = Coordinator::new(cfg, Box::new(SlaqPolicy::deterministic()));
                assert_eq!(c.threads(), threads);
                sim::submit_templates(&mut c, &templates, src_seed);
                c.run_until(60.0);
                c.into_trace()
            };
            let serial = run(1);
            for threads in [2usize, 4] {
                let par = run(threads);
                assert_eq!(serial.epochs.len(), par.epochs.len());
                for (a, b) in serial.epochs.iter().zip(&par.epochs) {
                    assert_eq!(a.active_jobs, b.active_jobs, "active sets diverged at t={}", a.time);
                    assert_eq!(a.refits, b.refits, "refit counts diverged at t={}", a.time);
                    assert_eq!(a.dirty_jobs, b.dirty_jobs);
                    assert_eq!(a.entries.len(), b.entries.len());
                    for (x, y) in a.entries.iter().zip(&b.entries) {
                        assert_eq!(x.job, y.job);
                        assert_eq!(
                            x.cores, y.cores,
                            "allocations diverged at t={} ({} threads)",
                            a.time, threads
                        );
                        assert_eq!(
                            x.loss, y.loss,
                            "losses diverged at t={} ({} threads)",
                            a.time, threads
                        );
                    }
                }
                assert_eq!(serial.jobs.len(), par.jobs.len());
                for (a, b) in serial.jobs.iter().zip(&par.jobs) {
                    assert_eq!(a.id, b.id);
                    assert_eq!(a.completion, b.completion, "completion diverged for job {}", a.id);
                    assert_eq!(a.samples, b.samples, "loss samples diverged for job {}", a.id);
                }
            }
        });
    }

    #[test]
    fn parallel_pipeline_records_the_gain_split() {
        // threads > 1: the gain-table build is timed as its own epoch
        // split; threads = 1: the serial reference path never builds one.
        let mut parallel = Coordinator::new(
            CoordinatorConfig { threads: 2, ..small_cluster() },
            Box::new(SlaqPolicy::new()),
        );
        assert_eq!(parallel.threads(), 2);
        for id in 0..4 {
            parallel.submit(mk_spec(id, 0.0, CurveKind::Exponential), exp_source(id + 1, 0.9));
        }
        parallel.step_epoch();
        parallel.step_epoch();
        assert!(
            parallel.sched_context().gain_table().is_none(),
            "recording the epoch must retire its table"
        );

        let mut serial = Coordinator::new(
            CoordinatorConfig { threads: 1, ..small_cluster() },
            Box::new(SlaqPolicy::new()),
        );
        for id in 0..4 {
            serial.submit(mk_spec(id, 0.0, CurveKind::Exponential), exp_source(id + 1, 0.9));
        }
        serial.step_epoch();
        assert_eq!(
            serial.last_epoch().unwrap().gain_nanos,
            0,
            "serial reference path must not pay a table build"
        );

        // A policy that never reads gains must not be built a table, even
        // with workers available.
        let mut fair = Coordinator::new(
            CoordinatorConfig { threads: 2, ..small_cluster() },
            Box::new(FairPolicy::new()),
        );
        for id in 0..4 {
            fair.submit(mk_spec(id, 0.0, CurveKind::Exponential), exp_source(id + 1, 0.9));
        }
        fair.step_epoch();
        assert_eq!(
            fair.last_epoch().unwrap().gain_nanos,
            0,
            "gain-blind policies must skip the table build"
        );
    }

    #[test]
    fn flat_topology_locality_layer_is_a_noop() {
        // On a single rack every span is ≤ 1, so even a punitive
        // locality model must leave the whole trace bit-identical to a
        // zero-penalty run — the invariant that keeps the
        // quality-fidelity suite green unchanged.
        use crate::testkit::{forall, sim};
        forall("flat ⇒ locality no-op", 4, |g| {
            let templates = sim::random_churn_templates(g, 10, 25.0);
            let src_seed = g.u64();
            let run = |locality: LocalityModel| {
                let cfg = CoordinatorConfig {
                    cluster: ClusterSpec { nodes: 3, cores_per_node: 8 },
                    topology: TopologySpec::Flat,
                    locality,
                    epoch_secs: 2.0,
                    threads: 1,
                    ..Default::default()
                };
                let mut c = Coordinator::new(cfg, Box::new(SlaqPolicy::deterministic()));
                sim::submit_templates(&mut c, &templates, src_seed);
                c.run_until(50.0);
                c.into_trace()
            };
            let off = run(LocalityModel::none());
            let punitive = run(LocalityModel {
                slowdown_per_extra_rack: 5.0,
                max_slowdown: 50.0,
            });
            assert_eq!(off.epochs.len(), punitive.epochs.len());
            for (a, b) in off.epochs.iter().zip(&punitive.epochs) {
                assert_eq!(a.cross_rack_moves, 0);
                assert_eq!(b.cross_rack_moves, 0);
                assert_eq!(a.entries.len(), b.entries.len());
                for (x, y) in a.entries.iter().zip(&b.entries) {
                    assert!(x.rack_span <= 1, "flat span above 1");
                    assert_eq!(x.rack_span, y.rack_span);
                    assert_eq!(x.cores, y.cores, "grants diverged at t={}", a.time);
                    assert_eq!(x.loss, y.loss, "losses diverged at t={}", a.time);
                }
            }
            for (a, b) in off.jobs.iter().zip(&punitive.jobs) {
                assert!(a.max_rack_span <= 1);
                assert_eq!(a.completion, b.completion);
                assert_eq!(a.samples, b.samples, "loss samples diverged for job {}", a.id);
            }
        });
    }

    #[test]
    fn multi_rack_pipeline_is_bit_identical_at_any_thread_count() {
        // The locality tie-break must stay deterministic through the
        // parallel epoch pipeline: on a multi-rack topology with the
        // penalty engaged, serial and sharded runs of `slaq-det` must
        // agree bitwise — grants, losses, rack spans, cross-rack moves,
        // completions.
        use crate::testkit::{forall, sim};
        forall("multi-rack threads=1 ≡ threads=N", 3, |g| {
            let templates = sim::random_churn_templates(g, 10, 25.0);
            let src_seed = g.u64();
            let run = |threads: usize| {
                let cfg = CoordinatorConfig {
                    cluster: ClusterSpec { nodes: 4, cores_per_node: 8 },
                    topology: TopologySpec::Uniform { zones: 2, racks_per_zone: 2 },
                    epoch_secs: 2.0,
                    threads,
                    ..Default::default()
                };
                let mut c = Coordinator::new(cfg, Box::new(SlaqPolicy::deterministic()));
                sim::submit_templates(&mut c, &templates, src_seed);
                c.run_until(50.0);
                c.into_trace()
            };
            let serial = run(1);
            for threads in [2usize, 4] {
                let par = run(threads);
                assert_eq!(serial.epochs.len(), par.epochs.len());
                for (a, b) in serial.epochs.iter().zip(&par.epochs) {
                    assert_eq!(a.cross_rack_moves, b.cross_rack_moves, "t={}", a.time);
                    assert_eq!(a.entries.len(), b.entries.len());
                    for (x, y) in a.entries.iter().zip(&b.entries) {
                        assert_eq!(x.job, y.job);
                        assert_eq!(x.cores, y.cores, "t={} ({threads} threads)", a.time);
                        assert_eq!(x.loss, y.loss, "t={} ({threads} threads)", a.time);
                        assert_eq!(
                            x.rack_span, y.rack_span,
                            "spans diverged at t={} ({threads} threads)",
                            a.time
                        );
                    }
                }
                for (a, b) in serial.jobs.iter().zip(&par.jobs) {
                    assert_eq!(a.max_rack_span, b.max_rack_span, "job {}", a.id);
                    assert_eq!(a.completion, b.completion, "job {}", a.id);
                    assert_eq!(a.samples, b.samples, "job {}", a.id);
                }
            }
        });
    }

    #[test]
    fn sharded_single_shard_is_bit_identical_to_flat() {
        // The sharded tentpole's anchor invariant: on a single-zone
        // topology the sharded pipeline degenerates to one shard whose
        // broker budget is always the whole capacity, and must be
        // indistinguishable from the flat coordinator — same grants,
        // losses, completions, bit for bit — at any thread count and any
        // broker cadence.
        use crate::testkit::{forall, sim};
        forall("sharded(1 zone) ≡ flat", 4, |g| {
            let templates = sim::random_churn_templates(g, 12, 30.0);
            let src_seed = g.u64();
            let broker_epochs = g.usize_in(1, 6);
            let run = |sharded: bool, threads: usize| {
                let cfg = CoordinatorConfig {
                    cluster: ClusterSpec { nodes: 3, cores_per_node: 8 },
                    epoch_secs: 2.0,
                    threads,
                    sharded,
                    broker_epochs,
                    ..Default::default()
                };
                let mut c = Coordinator::new(cfg, Box::new(SlaqPolicy::deterministic()));
                assert_eq!(c.shard_count(), usize::from(sharded));
                sim::submit_templates(&mut c, &templates, src_seed);
                c.run_until(60.0);
                c.into_trace()
            };
            let flat = run(false, 1);
            for threads in [1usize, 2, 4] {
                let shard = run(true, threads);
                assert_eq!(flat.epochs.len(), shard.epochs.len());
                for (a, b) in flat.epochs.iter().zip(&shard.epochs) {
                    assert_eq!(a.entries.len(), b.entries.len());
                    for (x, y) in a.entries.iter().zip(&b.entries) {
                        assert_eq!(x.job, y.job);
                        assert_eq!(
                            x.cores, y.cores,
                            "grants diverged at t={} ({threads} threads)",
                            a.time
                        );
                        assert_eq!(
                            x.loss, y.loss,
                            "losses diverged at t={} ({threads} threads)",
                            a.time
                        );
                    }
                }
                assert_eq!(flat.jobs.len(), shard.jobs.len());
                for (a, b) in flat.jobs.iter().zip(&shard.jobs) {
                    assert_eq!(a.completion, b.completion, "job {}", a.id);
                    assert_eq!(a.samples, b.samples, "job {}", a.id);
                }
            }
        });
    }

    #[test]
    fn multi_zone_sharded_trace_is_invariant_to_thread_count() {
        // The sharded `slaq-det` determinism guarantee: for a fixed shard
        // count, traces are bit-identical at every thread count — shard
        // tasks own disjoint state, grants merge through fixed index
        // lists, and the broker split is a pure function of demand.
        use crate::testkit::{forall, sim};
        forall("sharded zones=2: threads=1 ≡ threads=N", 3, |g| {
            let templates = sim::random_churn_templates(g, 12, 30.0);
            let src_seed = g.u64();
            let run = |threads: usize| {
                let cfg = CoordinatorConfig {
                    cluster: ClusterSpec { nodes: 4, cores_per_node: 8 },
                    topology: TopologySpec::Uniform { zones: 2, racks_per_zone: 2 },
                    epoch_secs: 2.0,
                    threads,
                    sharded: true,
                    broker_epochs: 4,
                    ..Default::default()
                };
                let mut c = Coordinator::new(cfg, Box::new(SlaqPolicy::deterministic()));
                assert_eq!(c.shard_count(), 2);
                sim::submit_templates(&mut c, &templates, src_seed);
                c.run_until(50.0);
                c.into_trace()
            };
            let serial = run(1);
            for threads in [2usize, 4] {
                let par = run(threads);
                assert_eq!(serial.epochs.len(), par.epochs.len());
                for (a, b) in serial.epochs.iter().zip(&par.epochs) {
                    assert_eq!(a.entries.len(), b.entries.len());
                    for (x, y) in a.entries.iter().zip(&b.entries) {
                        assert_eq!(x.job, y.job);
                        assert_eq!(x.cores, y.cores, "t={} ({threads} threads)", a.time);
                        assert_eq!(x.loss, y.loss, "t={} ({threads} threads)", a.time);
                        assert_eq!(x.rack_span, y.rack_span, "t={} ({threads} threads)", a.time);
                    }
                }
                for (a, b) in serial.jobs.iter().zip(&par.jobs) {
                    assert_eq!(a.completion, b.completion, "job {}", a.id);
                    assert_eq!(a.samples, b.samples, "job {}", a.id);
                }
            }
        });
    }

    #[test]
    fn shard_budgets_conserve_capacity_over_a_run() {
        // Work conservation end to end: the zone-keyed seed budgets and
        // every broker rebalance must keep Σ budgets == capacity.
        let cfg = CoordinatorConfig {
            cluster: ClusterSpec { nodes: 4, cores_per_node: 8 },
            topology: TopologySpec::Uniform { zones: 2, racks_per_zone: 1 },
            epoch_secs: 2.0,
            threads: 2,
            sharded: true,
            broker_epochs: 3,
            ..Default::default()
        };
        let mut c = Coordinator::new(cfg, Box::new(SlaqPolicy::deterministic()));
        assert_eq!(c.shard_count(), 2);
        assert_eq!(c.shard_budgets().iter().sum::<u32>(), 32, "zone-keyed seed budgets");
        for id in 0..10 {
            c.submit(mk_spec(id, 0.4 * id as f64, CurveKind::Exponential), exp_source(id + 1, 0.9));
        }
        for _ in 0..12 {
            c.step_epoch();
            assert_eq!(
                c.shard_budgets().iter().sum::<u32>(),
                32,
                "broker violated work conservation"
            );
        }
        c.pool().check_invariants();
    }

    #[test]
    fn dropping_the_coordinator_joins_its_worker_pool() {
        use std::sync::atomic::Ordering;
        let cfg = CoordinatorConfig { threads: 4, ..small_cluster() };
        let mut c = Coordinator::new(cfg, Box::new(SlaqPolicy::new()));
        for id in 0..4 {
            c.submit(mk_spec(id, 0.0, CurveKind::Exponential), exp_source(id + 1, 0.9));
        }
        c.step_epoch();
        let live = c.worker_live_counter().expect("threads > 1 implies a pool");
        assert_eq!(live.load(Ordering::SeqCst), 4, "pool created once, in new()");
        drop(c);
        assert_eq!(live.load(Ordering::SeqCst), 0, "worker threads leaked past drop");
    }

    #[test]
    fn locality_penalty_slows_fragmented_jobs() {
        // One 16-core job on 2 × 8-core nodes. With the nodes in separate
        // racks the placement spans 2 racks and (at +100% per extra rack)
        // every iteration takes twice as long as on the flat variant —
        // the trace must show the span, the slowdown and the cross-rack
        // spill.
        let run = |topology: TopologySpec| {
            let cfg = CoordinatorConfig {
                cluster: ClusterSpec { nodes: 2, cores_per_node: 8 },
                topology,
                locality: LocalityModel { slowdown_per_extra_rack: 1.0, max_slowdown: 4.0 },
                epoch_secs: 2.0,
                threads: 1,
                ..Default::default()
            };
            let mut c = Coordinator::new(cfg, Box::new(SlaqPolicy::new()));
            let mut spec = mk_spec(0, 0.0, CurveKind::Exponential);
            spec.max_cores = 16;
            spec.target_fraction = 0.99999; // keep running through the window
            c.submit(spec, exp_source(1, 0.97));
            c.run_until(20.0);
            c.into_trace()
        };
        let flat = run(TopologySpec::Flat);
        let split = run(TopologySpec::Uniform { zones: 1, racks_per_zone: 2 });

        assert_eq!(flat.jobs[0].max_rack_span, 1);
        assert_eq!(split.jobs[0].max_rack_span, 2);
        // The 16-core grant spills one node's worth of cores across racks
        // in the first placement epoch, and never moves again.
        assert_eq!(split.epochs[0].cross_rack_moves, 8);
        assert!(split.epochs.iter().skip(1).all(|e| e.cross_rack_moves == 0));
        assert!(split.epochs.iter().all(|e| e.max_rack_span() == 2));
        assert!((split.epochs[0].mean_rack_span() - 2.0).abs() < 1e-12);
        // Fragmentation halves iteration throughput.
        let iters = |t: &Trace| t.jobs[0].samples.last().map(|s| s.1).unwrap_or(0);
        let (fi, si) = (iters(&flat), iters(&split));
        assert!(
            si * 2 <= fi + 2,
            "2x slowdown should halve progress: flat {fi} vs split {si} iterations"
        );
        assert!(si > 0, "the fragmented job must still make progress");
    }

    #[test]
    fn slaq_prioritizes_fresh_jobs_over_nearly_converged() {
        // Job 0 starts at t=0 and is deep into its convergence tail when
        // job 1 arrives at t=30 with maximal quality potential. SLAQ should
        // shift the cores to job 1 (paper Fig 3 behaviour).
        let cfg = CoordinatorConfig {
            cluster: ClusterSpec { nodes: 2, cores_per_node: 16 },
            epoch_secs: 2.0,
            ..Default::default()
        };
        let mut c = Coordinator::new(cfg, Box::new(SlaqPolicy::new()));
        let heavy = CostModel::new(0.1, 32.0); // iter_time(32 cores) = 1.1s
        let mut old = mk_spec(0, 0.0, CurveKind::Exponential);
        old.target_fraction = 0.9999; // keeps running through a long tail
        old.cost = heavy;
        c.submit(old, exp_source(1, 0.9));
        let mut fresh = mk_spec(1, 30.0, CurveKind::Exponential);
        fresh.cost = heavy;
        c.submit(fresh, exp_source(2, 0.9));
        c.run_until(44.0);
        let trace = c.into_trace();
        // Epochs after job 1 has bootstrapped (a few observations).
        let late: Vec<_> = trace
            .epochs
            .iter()
            .filter(|e| e.time >= 34.0 && e.entries.len() == 2)
            .collect();
        assert!(!late.is_empty(), "both jobs should be running after t=34");
        let (mut cores0, mut cores1) = (0u64, 0u64);
        for e in late {
            for en in &e.entries {
                if en.job == 0 {
                    cores0 += en.cores as u64;
                } else {
                    cores1 += en.cores as u64;
                }
            }
        }
        assert!(
            cores1 > 3 * cores0,
            "fresh job should out-receive tail job: {cores1} vs {cores0}"
        );
    }

    #[test]
    fn slaq_beats_fair_on_average_quality() {
        // The paper's Fig 4 scenario in miniature: a stream of homogeneous
        // jobs under contention. Under fair scheduling, jobs deep in their
        // convergence tail keep their equal share; SLAQ reassigns those
        // cores to fresh, high-potential jobs, lowering the average
        // normalized loss across running jobs.
        fn run(policy: Box<dyn Policy>) -> f64 {
            let cfg = CoordinatorConfig {
                cluster: ClusterSpec { nodes: 2, cores_per_node: 8 },
                epoch_secs: 2.0,
                ..Default::default()
            };
            let mut c = Coordinator::new(cfg, policy);
            for id in 0..12u64 {
                let mut spec = mk_spec(id, 8.0 * id as f64, CurveKind::Exponential);
                spec.cost = CostModel::new(0.05, 8.0);
                spec.target_fraction = 0.98; // long tail before completion
                c.submit(spec, exp_source(id + 10, 0.9));
            }
            c.run_until(160.0);
            let trace = c.into_trace();
            // Average normalized loss across epochs and active jobs (Fig 4).
            let mut total = 0.0;
            let mut count = 0usize;
            for e in &trace.epochs {
                for en in &e.entries {
                    let j = trace.job(en.job).unwrap();
                    total += j.norm_loss(en.loss);
                    count += 1;
                }
            }
            total / count.max(1) as f64
        }
        let slaq = run(Box::new(SlaqPolicy::new()));
        let fair = run(Box::new(FairPolicy::new()));
        assert!(
            slaq < fair,
            "slaq avg normalized loss {slaq} should beat fair {fair}"
        );
    }

    #[test]
    fn fault_knobs_are_inert_without_faults() {
        // With an empty fault schedule every fault hook must be a
        // provable no-op: varying the checkpoint cadence cannot perturb a
        // single bit of the trace, and the fault counters stay zero.
        let run = |checkpoint_epochs: usize| {
            let cfg = CoordinatorConfig { checkpoint_epochs, ..small_cluster() };
            let mut c = Coordinator::new(cfg, Box::new(SlaqPolicy::deterministic()));
            c.submit(mk_spec(0, 0.0, CurveKind::Exponential), exp_source(1, 0.9));
            c.submit(mk_spec(1, 4.0, CurveKind::Exponential), exp_source(2, 0.92));
            c.run_until(40.0);
            assert_eq!(c.parked_jobs(), Vec::<u64>::new());
            assert_eq!(c.failed_epochs(), 0);
            c.into_trace()
        };
        let base = run(4);
        let other = run(1);
        assert_eq!(base.epochs.len(), other.epochs.len());
        for (a, b) in base.epochs.iter().zip(&other.epochs) {
            assert_eq!((a.lost_cores, a.replacements, a.failed_epochs), (0, 0, 0));
            assert_eq!(a.entries.len(), b.entries.len());
            for (x, y) in a.entries.iter().zip(&b.entries) {
                assert_eq!((x.job, x.cores), (y.job, y.cores));
                assert_eq!(x.loss.to_bits(), y.loss.to_bits());
            }
        }
    }

    #[test]
    fn node_failure_displaces_and_replaces_on_survivors() {
        // 2 × 16 cores, one 32-core job. Node 1 crash-stops at epoch 2:
        // the job loses 16 cores, is re-placed onto the survivor the same
        // epoch (a replacement, not a failed epoch), and nothing ever
        // lands on the dead node again.
        let cfg = CoordinatorConfig {
            faults: FaultSpec::none().with_crash(2, 1),
            ..small_cluster()
        };
        let mut c = Coordinator::new(cfg, Box::new(SlaqPolicy::deterministic()));
        let mut spec = mk_spec(0, 0.0, CurveKind::Exponential);
        spec.target_fraction = 0.99999;
        c.submit(spec, exp_source(1, 0.995));
        c.run_until(30.0);
        assert!(c.pool().is_dead(1));
        assert_eq!(c.failed_epochs(), 0, "the survivor had room");
        assert_eq!(c.parked_jobs(), Vec::<u64>::new());
        for (_, nodes) in c.pool().placements_snapshot() {
            assert!(nodes.iter().all(|&(node, _)| node != 1), "grant on a dead node");
        }
        let trace = c.into_trace();
        assert_eq!(trace.epochs[2].lost_cores, 16);
        assert_eq!(trace.epochs[2].replacements, 1);
        assert!(trace.epochs.iter().all(|e| e.failed_epochs == 0));
        // From the failure on, grants fit the surviving capacity.
        for e in trace.epochs.iter().skip(2) {
            let total: u32 = e.entries.iter().map(|en| en.cores).sum();
            assert!(total <= 16, "overcommitted {total} cores at t={}", e.time);
        }
    }

    #[test]
    fn cluster_blackout_parks_with_exponential_backoff() {
        // Both nodes black out at epoch 1 and recover at epoch 3. The
        // displaced job fails placement at epoch 1 (parks, backoff 1),
        // fails the retry at epoch 2 (re-parks, backoff 2 — so it does
        // not even request at epoch 3) and re-places at epoch 4.
        let cfg = CoordinatorConfig {
            faults: FaultSpec::none().with_blackout(1, 0, 2).with_blackout(1, 1, 2),
            ..small_cluster()
        };
        let mut c = Coordinator::new(cfg, Box::new(SlaqPolicy::deterministic()));
        let mut spec = mk_spec(0, 0.0, CurveKind::Exponential);
        spec.target_fraction = 0.99999;
        c.submit(spec, exp_source(1, 0.995));
        c.run_until(12.0); // 6 epochs
        assert_eq!(c.parked_jobs(), Vec::<u64>::new());
        assert_eq!(c.failed_epochs(), 2);
        let trace = c.into_trace();
        let cores_at = |i: usize| trace.epochs[i].entries[0].cores;
        assert_eq!(trace.epochs[1].lost_cores, 32);
        assert_eq!(trace.epochs[1].failed_epochs, 1);
        assert_eq!(trace.epochs[2].failed_epochs, 2);
        assert_eq!(cores_at(1), 0);
        assert_eq!(cores_at(2), 0);
        assert_eq!(cores_at(3), 0, "still parked when capacity returns");
        assert!(cores_at(4) > 0, "park expired onto recovered capacity");
        assert_eq!(trace.epochs[4].replacements, 1);
    }

    #[test]
    fn misbehaving_reports_fall_back_to_the_fair_share_floor() {
        // Job 0 reports garbage (10^9× spikes) from its second sample on:
        // the predictor quarantines it, the gain oracle falls back to the
        // degraded fair-share floor, and the job is clamped to its fair
        // share while the healthy job keeps its full allocation. The
        // spare half of the cluster still flows to the degraded job — it
        // is contained, not starved.
        use crate::coordinator::source::ReplaySource;
        let cfg = small_cluster(); // 2 × 16 cores
        let mut c = Coordinator::new(cfg, Box::new(SlaqPolicy::deterministic()));
        let mut bad = mk_spec(0, 0.0, CurveKind::Exponential);
        bad.target_fraction = 0.99999;
        let mut spikes = vec![1.0];
        spikes.resize(4096, 1.0e9);
        c.submit(bad, Box::new(ReplaySource::new(spikes)));
        let mut good = mk_spec(1, 0.0, CurveKind::Exponential);
        good.max_cores = 16;
        good.target_fraction = 0.99999;
        c.submit(good, exp_source(2, 0.995));
        c.run_until(30.0);
        assert!(c.degraded_transitions() >= 1, "degraded fallback never tripped");
        let trace = c.into_trace();
        // After the quarantine budget (3 rejected samples) has certainly
        // tripped, the degraded job is capped at fair share (32/2 = 16)
        // but keeps receiving the cores the healthy job cannot use.
        for e in trace.epochs.iter().filter(|e| e.time >= 10.0) {
            let bad_cores = e.entries.iter().find(|en| en.job == 0).map(|en| en.cores);
            let good_cores = e.entries.iter().find(|en| en.job == 1).map(|en| en.cores);
            if let (Some(b), Some(g)) = (bad_cores, good_cores) {
                assert!(b <= 16, "degraded job exceeded fair share: {b} at t={}", e.time);
                assert!(b > 0, "degraded job starved at t={}", e.time);
                assert!(g > 0, "healthy job starved at t={}", e.time);
            }
        }
    }

    #[test]
    fn transition_knobs_are_inert_when_free() {
        // The zero-cost contract at the coordinator level: with the
        // default (free) TransitionModel the entire voluntary-restart
        // path is gated off, so neither the planner flag nor the
        // checkpoint cadence can move a bit of the trace — flat and
        // 8-zone sharded, serial and pooled alike.
        use crate::testkit::crash::assert_trace_eq;
        use crate::testkit::{sim, Gen};
        for (threads, sharded) in [(1, false), (4, false), (1, true), (4, true)] {
            let cfg = if sharded {
                CoordinatorConfig {
                    cluster: ClusterSpec { nodes: 16, cores_per_node: 4 },
                    topology: TopologySpec::Uniform { zones: 8, racks_per_zone: 1 },
                    epoch_secs: 2.0,
                    threads,
                    sharded: true,
                    broker_epochs: 3,
                    ..Default::default()
                }
            } else {
                CoordinatorConfig { threads, ..small_cluster() }
            };
            let mut g = Gen::from_seed(0x7a57 + threads as u64);
            let templates = sim::random_churn_templates(&mut g, 10, 16.0);
            let source_seed = g.u64();
            let run = |cfg: CoordinatorConfig| {
                let mut c = Coordinator::new(cfg, policy_by_name("slaq-det").unwrap());
                sim::submit_templates(&mut c, &templates, source_seed);
                for _ in 0..12 {
                    c.step_epoch();
                }
                c.into_trace()
            };
            let base = run(cfg.clone());
            let variant = run(CoordinatorConfig {
                price_transitions: false,
                checkpoint_epochs: 1,
                ..cfg
            });
            let what = format!("free-transition inertness t{threads} sharded={sharded}");
            assert_trace_eq(&base, &variant, &what);
            assert!(
                base.epochs.iter().all(|e| e.voluntary_restarts == 0),
                "{what}: free transitions charged a restart"
            );
        }
    }

    #[test]
    fn voluntary_shrink_charges_restart_debt() {
        // Job 0 holds the whole 2×16-core cluster; job 1 arrives at t=6
        // and forces a shrink. With the free model the shrink costs
        // nothing; with a non-free one the simulator charges the rewind
        // + warmup on job 0's iteration clock (whatever the planner
        // thought of the move — both runs here plan blind so the charge
        // is the only difference), which costs iterations by the horizon.
        let run = |transition: TransitionModel| {
            let cfg =
                CoordinatorConfig { transition, price_transitions: false, ..small_cluster() };
            let mut c = Coordinator::new(cfg, Box::new(SlaqPolicy::deterministic()));
            let mut a = mk_spec(0, 0.0, CurveKind::Exponential);
            a.target_fraction = 0.99999;
            c.submit(a, exp_source(1, 0.995));
            let mut b = mk_spec(1, 6.0, CurveKind::Exponential);
            b.target_fraction = 0.99999;
            c.submit(b, exp_source(2, 0.995));
            c.run_until(24.0);
            c.into_trace()
        };
        let free = run(TransitionModel::default());
        let priced = run(TransitionModel {
            checkpoint_write_iters: 0.0,
            restore_iters: 4,
            warmup_iters_per_state_sec: 0.0,
        });
        assert!(free.epochs.iter().all(|e| e.voluntary_restarts == 0));
        let charged: u32 = priced.epochs.iter().map(|e| e.voluntary_restarts).sum();
        assert!(charged >= 1, "the forced shrink at job 1's arrival was never charged");
        let iters = |t: &Trace| t.jobs.iter().find(|j| j.id == 0).unwrap().samples.len();
        assert!(
            iters(&priced) < iters(&free),
            "restart debt must cost job 0 iterations: {} vs {}",
            iters(&priced),
            iters(&free),
        );
    }

    #[test]
    fn elastic_events_retarget_cap_and_slow_the_clock() {
        // One job alone on 2×16 cores with a scheduled mid-training
        // shrink: at iteration 12 its cap drops from 32 to 4 and every
        // iteration starts doing `work_scale`× the work. The adapted cap
        // must bind every later grant, and the heavier variant must
        // complete fewer iterations over the same horizon. The
        // transition model stays free here — adaptation is a workload
        // property, not a pricing knob.
        use crate::coordinator::ElasticSpec;
        let run = |work_scale: f64| {
            let mut spec = mk_spec(0, 0.0, CurveKind::Exponential);
            spec.target_fraction = 0.99999;
            spec.elastic = vec![ElasticSpec { at_iteration: 12, max_cores: 4, work_scale }];
            let mut c = Coordinator::new(small_cluster(), Box::new(SlaqPolicy::deterministic()));
            c.submit(spec, exp_source(1, 0.995));
            c.run_until(20.0);
            c.into_trace()
        };
        let light = run(1.0);
        let heavy = run(2.0);
        for t in [&light, &heavy] {
            let cores: Vec<u32> = t
                .epochs
                .iter()
                .filter_map(|e| e.entries.iter().find(|en| en.job == 0).map(|en| en.cores))
                .collect();
            assert!(cores[0] > 4, "the pre-event cap should allow a wide grant");
            let first_capped =
                cores.iter().position(|&c| c <= 4).expect("the shrink event must apply");
            assert!(
                cores[first_capped..].iter().all(|&c| c <= 4),
                "a grant exceeded the adapted cap after the event applied: {cores:?}"
            );
        }
        let iters = |t: &Trace| t.jobs[0].samples.len();
        assert!(
            iters(&heavy) < iters(&light),
            "doubled per-iteration work must slow the iteration clock: {} vs {}",
            iters(&heavy),
            iters(&light),
        );
    }
}
