//! Persistent worker pool for the epoch pipeline's data-parallel stages.
//!
//! The PR 4 pipeline spawned fresh `std::thread::scope` workers for every
//! epoch's refit and gain-table stages — two rounds of thread creation
//! plus teardown per epoch, which starts to dominate the stage cost once
//! per-shard work drops to microseconds (exactly the regime the sharded
//! coordinator targets). This pool creates its workers once (in
//! `Coordinator::new`), feeds them boxed tasks over per-worker channels,
//! and joins them when the pool drops.
//!
//! ## Determinism
//!
//! Task `i` of a batch is pinned to worker `i % workers` in submission
//! order, and each worker drains its channel FIFO — the assignment of
//! work to workers is a pure function of the batch, never of thread
//! timing. Pipeline outputs stay bit-identical for the same reason they
//! did under `thread::scope`: every task writes a disjoint, preassigned
//! slot, so nothing depends on completion order.
//!
//! ## Borrowed tasks
//!
//! [`WorkerPool::run`] accepts closures that borrow the caller's stack
//! (a `'scope` lifetime) even though the worker threads are `'static`.
//! This is sound because `run` does not return — normally or by panic —
//! until every submitted task has completed (it counts completion
//! messages), so the borrows outlive all worker-side use: the same
//! guarantee `std::thread::scope` makes, enforced by blocking instead of
//! by a scope.
//!
//! ## Panics
//!
//! A panicking task is caught on its worker (the worker thread itself
//! never dies), reported back over the batch's completion channel, and
//! re-raised on the caller once the whole batch has drained — a worker
//! panic surfaces as a panic in the calling epoch, never as a hang, a
//! leaked thread, or a half-poisoned pool, and the pool remains usable
//! for the next batch.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

/// One task's outcome: `Err` carries a caught panic payload to re-raise.
type Outcome = Result<(), Box<dyn std::any::Any + Send + 'static>>;

/// A boxed unit of work with the pool's (erased) `'static` lifetime.
type Task = Box<dyn FnOnce() + Send + 'static>;

/// Packet sent to a worker: the task plus its batch's completion channel.
type Packet = (Task, Sender<Outcome>);

/// A fixed-size pool of persistent worker threads (see the module docs).
pub struct WorkerPool {
    /// One channel per worker; dropping them all shuts the pool down.
    senders: Vec<Sender<Packet>>,
    handles: Vec<JoinHandle<()>>,
    /// Workers whose thread loop is currently running (each worker
    /// increments it before entering the loop and decrements on exit) —
    /// the observable the shutdown tests key on.
    live: Arc<AtomicUsize>,
}

impl WorkerPool {
    /// Spawn `workers` persistent worker threads (`workers >= 1`).
    pub fn new(workers: usize) -> Self {
        assert!(workers >= 1, "a worker pool needs at least one worker");
        let live = Arc::new(AtomicUsize::new(0));
        let mut senders = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for _ in 0..workers {
            let (tx, rx) = channel::<Packet>();
            let live = Arc::clone(&live);
            live.fetch_add(1, Ordering::SeqCst);
            handles.push(std::thread::spawn(move || {
                while let Ok((task, done)) = rx.recv() {
                    let outcome = catch_unwind(AssertUnwindSafe(task));
                    // A send can only fail while the pool is mid-drop and
                    // the caller's batch receiver is gone; nothing to do.
                    let _ = done.send(outcome);
                }
                live.fetch_sub(1, Ordering::SeqCst);
            }));
            senders.push(tx);
        }
        Self { senders, handles, live }
    }

    /// Number of worker threads in the pool.
    pub fn workers(&self) -> usize {
        self.senders.len()
    }

    /// Workers whose thread loop is currently running. `workers()` while
    /// the pool is alive; `0` once `Drop` has joined them (observed
    /// through a clone of the counter taken before the drop).
    pub fn live_workers(&self) -> usize {
        self.live.load(Ordering::SeqCst)
    }

    /// Clone of the live-worker counter, for observing shutdown after the
    /// pool itself is gone.
    pub fn live_counter(&self) -> Arc<AtomicUsize> {
        Arc::clone(&self.live)
    }

    /// Run a batch of tasks across the pool and block until every task
    /// has completed. Task `i` runs on worker `i % workers()`, in
    /// submission order within each worker.
    ///
    /// If any task panicked, the first submitted task's payload is
    /// re-raised *after* the whole batch has drained (no task can still
    /// be touching caller borrows when the panic propagates).
    pub fn run<'scope>(&self, tasks: Vec<Box<dyn FnOnce() + Send + 'scope>>) {
        if tasks.is_empty() {
            return;
        }
        let n = tasks.len();
        let (done_tx, done_rx) = channel::<Outcome>();
        for (i, task) in tasks.into_iter().enumerate() {
            // SAFETY: erasing `'scope` to `'static` is sound because this
            // function blocks until all `n` completion messages arrive
            // (even on the panic path), so every task — and every borrow
            // it captured — is finished with before `run` returns. The
            // sends below cannot fail while `&self` is alive: workers
            // only exit when `Drop` closes their channels.
            let task: Task = unsafe {
                std::mem::transmute::<Box<dyn FnOnce() + Send + 'scope>, Task>(task)
            };
            self.senders[i % self.senders.len()]
                .send((task, done_tx.clone()))
                .expect("worker thread exited while the pool is alive");
        }
        drop(done_tx);
        let mut first_panic: Option<Box<dyn std::any::Any + Send + 'static>> = None;
        for _ in 0..n {
            match done_rx.recv().expect("worker dropped a task without reporting it") {
                Ok(()) => {}
                Err(payload) => {
                    first_panic.get_or_insert(payload);
                }
            }
        }
        if let Some(payload) = first_panic {
            resume_unwind(payload);
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // Closing every channel lets each worker finish its queue and
        // exit its loop; joining guarantees no thread outlives the pool.
        self.senders.clear();
        for handle in self.handles.drain(..) {
            let _ = handle.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    fn boxed<'a>(f: impl FnOnce() + Send + 'a) -> Box<dyn FnOnce() + Send + 'a> {
        Box::new(f)
    }

    #[test]
    fn tasks_write_their_preassigned_slots() {
        let pool = WorkerPool::new(3);
        let mut slots = vec![0u64; 10];
        {
            let tasks = slots
                .iter_mut()
                .enumerate()
                .map(|(i, slot)| boxed(move || *slot = (i as u64 + 1) * 7))
                .collect();
            pool.run(tasks);
        }
        for (i, &v) in slots.iter().enumerate() {
            assert_eq!(v, (i as u64 + 1) * 7, "slot {i}");
        }
    }

    #[test]
    fn borrowed_stack_state_is_visible_after_run() {
        // The 'scope-erasure contract: tasks may borrow the caller's
        // stack, and the writes are visible once run() returns.
        let pool = WorkerPool::new(2);
        let data = vec![1u32, 2, 3, 4, 5, 6];
        let sum = AtomicU64::new(0);
        let chunks: Vec<&[u32]> = data.chunks(2).collect();
        let tasks = chunks
            .into_iter()
            .map(|chunk| {
                let sum = &sum;
                boxed(move || {
                    let s: u32 = chunk.iter().sum();
                    sum.fetch_add(s as u64, Ordering::SeqCst);
                })
            })
            .collect();
        pool.run(tasks);
        assert_eq!(sum.load(Ordering::SeqCst), 21);
    }

    #[test]
    fn tasks_pin_to_workers_in_submission_order() {
        // Task i runs on worker i % workers: with 2 workers, tasks 0 and 2
        // must share a thread, as must tasks 1 and 3 — and the two pairs
        // must be on different threads.
        let pool = WorkerPool::new(2);
        let mut tids: Vec<Option<std::thread::ThreadId>> = vec![None; 4];
        {
            let tasks = tids
                .iter_mut()
                .map(|slot| boxed(move || *slot = Some(std::thread::current().id())))
                .collect();
            pool.run(tasks);
        }
        let tids: Vec<_> = tids.into_iter().map(|t| t.unwrap()).collect();
        assert_eq!(tids[0], tids[2], "tasks 0 and 2 must pin to worker 0");
        assert_eq!(tids[1], tids[3], "tasks 1 and 3 must pin to worker 1");
        assert_ne!(tids[0], tids[1], "two workers must be distinct threads");
    }

    #[test]
    fn empty_batches_are_a_noop() {
        let pool = WorkerPool::new(2);
        pool.run(Vec::new());
        assert_eq!(pool.live_workers(), 2);
    }

    #[test]
    fn drop_joins_every_worker_thread() {
        let live = {
            let pool = WorkerPool::new(4);
            assert_eq!(pool.workers(), 4);
            assert_eq!(pool.live_workers(), 4);
            // Give the pool real work before shutdown.
            let counter = AtomicU64::new(0);
            let tasks = (0..8)
                .map(|_| {
                    let counter = &counter;
                    boxed(move || {
                        counter.fetch_add(1, Ordering::SeqCst);
                    })
                })
                .collect();
            pool.run(tasks);
            assert_eq!(counter.load(Ordering::SeqCst), 8);
            pool.live_counter()
            // pool drops here: channels close, workers exit, drop joins.
        };
        assert_eq!(
            live.load(Ordering::SeqCst),
            0,
            "worker threads leaked past the pool's drop"
        );
    }

    #[test]
    fn panicking_task_propagates_after_the_batch_drains() {
        let pool = WorkerPool::new(2);
        let mut slots = vec![0u32; 5];
        let caught = std::panic::catch_unwind(AssertUnwindSafe(|| {
            let tasks = slots
                .iter_mut()
                .enumerate()
                .map(|(i, slot)| {
                    boxed(move || {
                        if i == 2 {
                            panic!("task 2 exploded");
                        }
                        *slot = 1;
                    })
                })
                .collect();
            pool.run(tasks);
        }));
        let payload = caught.expect_err("worker panic must surface as an error");
        let msg = payload
            .downcast_ref::<&str>()
            .copied()
            .or_else(|| payload.downcast_ref::<String>().map(|s| s.as_str()))
            .unwrap_or("");
        assert!(msg.contains("task 2 exploded"), "unexpected payload: {msg}");
        // The batch drained fully before the panic propagated: every other
        // slot was written, and the pool is still fully usable.
        for (i, &v) in slots.iter().enumerate() {
            if i != 2 {
                assert_eq!(v, 1, "slot {i} must have been written");
            }
        }
        assert_eq!(pool.live_workers(), 2, "panic must not kill worker threads");
        let mut after = vec![0u32; 3];
        {
            let tasks = after.iter_mut().map(|s| boxed(move || *s = 9)).collect();
            pool.run(tasks);
        }
        assert_eq!(after, vec![9, 9, 9], "pool must stay usable after a panic");
    }
}
