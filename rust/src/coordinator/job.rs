//! Job model: spec, lifecycle state, per-epoch advancement.

use super::source::LossSource;
use crate::cluster::CostModel;
use crate::predictor::{CurveKind, OnlinePredictor};

/// One scheduled elasticity event: once the job reaches `at_iteration`,
/// its core cap and per-iteration work change. Models mid-training
/// adaptation from the workload zoo — batch-size ramps (more work per
/// iteration, wider parallelism) or late-phase shrink (the job gives
/// cores back once past its steep descent).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ElasticSpec {
    /// Iteration at which the event takes effect. Events are applied at
    /// epoch boundaries: the first epoch whose planning pass observes
    /// `job.iteration >= at_iteration` plans with the new shape.
    pub at_iteration: u64,
    /// New core cap (replaces [`JobSpec::max_cores`] in the planner's
    /// gain view and the allocator's request cap).
    pub max_cores: u32,
    /// Multiplier on the job's locality slowdown — the elastic proxy for
    /// "each iteration now does `work_scale`× the work". `1.0` is inert
    /// bit for bit.
    pub work_scale: f64,
}

impl ElasticSpec {
    /// Append to a durable-state buffer (see [`crate::util::codec`]).
    pub fn encode(&self, e: &mut crate::util::codec::Enc) {
        e.put_u64(self.at_iteration);
        e.put_u32(self.max_cores);
        e.put_f64(self.work_scale);
    }

    /// Inverse of [`ElasticSpec::encode`].
    pub fn decode(d: &mut crate::util::codec::Dec) -> std::io::Result<Self> {
        Ok(Self {
            at_iteration: d.u64()?,
            max_cores: d.u32()?,
            work_scale: d.f64()?,
        })
    }
}

/// Static description of a training job.
#[derive(Debug, Clone)]
pub struct JobSpec {
    /// Unique id; also the FIFO arrival order key.
    pub id: u64,
    /// Human-readable name, e.g. "logreg-mnist-lr0.1".
    pub name: String,
    /// Declared convergence family of the optimizer (paper §2 categories).
    pub kind: CurveKind,
    /// BSP iteration cost model.
    pub cost: CostModel,
    /// Maximum cores the job can use (its partition count).
    pub max_cores: u32,
    /// Arrival time (virtual seconds).
    pub arrival: f64,
    /// Fraction of total achievable loss reduction at which the job is
    /// considered converged (e.g. 0.99). Only applies when the loss source
    /// has a known floor.
    pub target_fraction: f64,
    /// Hard iteration cap (safety net; also the convergence criterion when
    /// no floor is known).
    pub max_iterations: u64,
    /// Optional user-provided target loss (paper §4): forwarded to the
    /// predictor as a hint for non-convex jobs whose loss curves do not
    /// fit the analytical families.
    pub target_hint: Option<f64>,
    /// Scheduled elasticity events, sorted by `at_iteration` ascending.
    /// Empty for the (overwhelmingly common) rigid job — the empty case
    /// is bit-identical to the pre-elastic coordinator. The spec is never
    /// mutated; the applied-prefix counter lives on [`Job`] so replay
    /// re-derives it deterministically.
    pub elastic: Vec<ElasticSpec>,
}

impl JobSpec {
    /// Append the spec to a durable-state buffer (see
    /// [`crate::util::codec`]); shared by the job snapshot codec and the
    /// WAL's submission records.
    pub fn encode(&self, e: &mut crate::util::codec::Enc) {
        e.put_u64(self.id);
        e.put_str(&self.name);
        e.put_u8(self.kind.to_byte());
        e.put_f64(self.cost.serial_secs);
        e.put_f64(self.cost.work_core_secs);
        e.put_f64(self.cost.overhead_per_core);
        e.put_u32(self.max_cores);
        e.put_f64(self.arrival);
        e.put_f64(self.target_fraction);
        e.put_u64(self.max_iterations);
        e.put_opt_f64(self.target_hint);
        e.put_usize(self.elastic.len());
        for ev in &self.elastic {
            ev.encode(e);
        }
    }

    /// Inverse of [`JobSpec::encode`].
    pub fn decode(d: &mut crate::util::codec::Dec) -> std::io::Result<Self> {
        let id = d.u64()?;
        let name = d.str()?;
        let kind = CurveKind::from_byte(d.u8()?)?;
        let cost = CostModel {
            serial_secs: d.f64()?,
            work_core_secs: d.f64()?,
            overhead_per_core: d.f64()?,
        };
        let max_cores = d.u32()?;
        let arrival = d.f64()?;
        let target_fraction = d.f64()?;
        let max_iterations = d.u64()?;
        let target_hint = d.opt_f64()?;
        let n = d.usize_()?;
        let mut elastic = Vec::with_capacity(n.min(1 << 12));
        for _ in 0..n {
            elastic.push(ElasticSpec::decode(d)?);
        }
        Ok(Self {
            id,
            name,
            kind,
            cost,
            max_cores,
            arrival,
            target_fraction,
            max_iterations,
            target_hint,
            elastic,
        })
    }
}

/// Lifecycle state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    /// Submitted, not yet activated by the coordinator.
    Pending,
    /// Active: holds cores and runs iterations.
    Running,
    /// Converged or hit its iteration cap.
    Completed,
    /// Withdrawn by the submitter before completing (event front-end
    /// `Cancel`); never runs again and holds no cores.
    Cancelled,
}

/// A live job inside the coordinator.
pub struct Job {
    /// Static spec.
    pub spec: JobSpec,
    /// Lifecycle state.
    pub state: JobState,
    /// Online convergence predictor (the scheduler's view of the job).
    pub predictor: OnlinePredictor,
    /// Loss oracle.
    pub source: Box<dyn LossSource>,
    /// Iterations completed.
    pub iteration: u64,
    /// Partial-progress credit (seconds toward the next iteration).
    pub credit: f64,
    /// Cores currently held.
    pub cores: u32,
    /// Widest rack span the job's placement ever had (0 until it holds
    /// cores on a cluster with topology; maintained by the coordinator).
    pub max_rack_span: u32,
    /// Initial loss (set on activation).
    pub initial_loss: f64,
    /// Completion time, once completed.
    pub completion_time: Option<f64>,
    /// Full loss trajectory `(time, iteration, loss)` — never truncated
    /// (the predictor's internal window is).
    pub loss_trace: Vec<(f64, u64, f64)>,
    /// Consecutive tiny-relative-delta count (floorless convergence check).
    small_delta_streak: u32,
    /// Iteration count at the job's most recent checkpoint epoch — the
    /// restart point after a node failure (maintained by the coordinator
    /// on its `checkpoint_epochs` cadence).
    pub ckpt_iteration: u64,
    /// Iterations the job must re-execute before making new progress
    /// again; set to `iteration - ckpt_iteration` when a failure evicts
    /// its cores — or to the rewind-plus-warmup debt of a voluntary
    /// shrink/migration when the coordinator prices transitions —
    /// consumed by [`Job::advance_with_locality`].
    pub pending_restart_iters: u64,
    /// How many leading entries of `spec.elastic` have taken effect —
    /// bumped by the coordinator's epoch loop once `iteration` passes an
    /// event's `at_iteration`. Monotone, replay-derived, and 0 for rigid
    /// jobs, so the pre-elastic coordinator is reproduced bit for bit.
    pub elastic_applied: u32,
}

/// Relative per-iteration improvement below which a job with an unknown
/// floor is considered converged (after [`STALL_STREAK`] consecutive hits).
const STALL_TOL: f64 = 1e-4;
/// Consecutive stalled iterations required to declare convergence.
const STALL_STREAK: u32 = 8;

impl Job {
    /// Construct a pending job.
    pub fn new(spec: JobSpec, source: Box<dyn LossSource>) -> Self {
        let kind = spec.kind;
        let mut predictor = OnlinePredictor::new(kind);
        if let Some(hint) = spec.target_hint {
            predictor.set_target_hint(hint);
        }
        Self {
            spec,
            state: JobState::Pending,
            predictor,
            source,
            iteration: 0,
            credit: 0.0,
            cores: 0,
            max_rack_span: 0,
            initial_loss: f64::NAN,
            completion_time: None,
            loss_trace: Vec::new(),
            small_delta_streak: 0,
            ckpt_iteration: 0,
            pending_restart_iters: 0,
            elastic_applied: 0,
        }
    }

    /// Core cap after the applied elastic events: the last applied
    /// event's `max_cores`, or the spec cap while none have fired.
    pub fn effective_max_cores(&self) -> u32 {
        match self.elastic_applied {
            0 => self.spec.max_cores,
            n => self.spec.elastic[n as usize - 1].max_cores,
        }
    }

    /// Per-iteration work multiplier after the applied elastic events
    /// (`1.0` while none have fired).
    pub fn work_scale(&self) -> f64 {
        match self.elastic_applied {
            0 => 1.0,
            n => self.spec.elastic[n as usize - 1].work_scale,
        }
    }

    /// Fold the job's elastic work multiplier into a locality slowdown.
    /// The `== 1.0` guard is a branch, not arithmetic, so rigid jobs
    /// (and unit-scale events) keep the unscaled slowdown bit for bit.
    pub fn work_scaled(&self, slowdown: f64) -> f64 {
        let scale = self.work_scale();
        if scale == 1.0 {
            slowdown
        } else {
            slowdown * scale
        }
    }

    /// Activate the job at time `t`: read the initial loss (iteration 0).
    pub fn activate(&mut self, t: f64) {
        assert_eq!(self.state, JobState::Pending);
        self.state = JobState::Running;
        self.initial_loss = self.source.loss_at(0);
        self.predictor.observe(0, self.initial_loss, t);
        self.loss_trace.push((t, 0, self.initial_loss));
    }

    /// Advance through the window `[t0, t0 + window)` holding `cores`
    /// cores. Completes iterations, feeds the predictor, and flips to
    /// `Completed` when the convergence criterion fires. Returns the number
    /// of iterations completed in this window.
    pub fn advance(&mut self, t0: f64, window: f64, cores: u32) -> u64 {
        self.advance_with_locality(t0, window, cores, 1.0)
    }

    /// [`Job::advance`] under a locality slowdown: every iteration is
    /// stretched by `slowdown` (≥ 1.0, from
    /// [`crate::cluster::LocalityModel::slowdown`] applied to the job's
    /// rack span), so fragmented placements genuinely converge slower.
    /// `slowdown = 1.0` reproduces the unscaled clock bit for bit.
    pub fn advance_with_locality(
        &mut self,
        t0: f64,
        window: f64,
        cores: u32,
        slowdown: f64,
    ) -> u64 {
        assert_eq!(self.state, JobState::Running);
        self.cores = cores;
        if cores == 0 {
            // Paused (allocation floor couldn't cover all jobs).
            return 0;
        }
        let iter_time = self.spec.cost.iter_time_scaled(cores, slowdown);
        let (n, new_credit) =
            self.spec
                .cost
                .iterations_in_window_scaled(window, cores, self.credit, slowdown);
        let credit0 = self.credit;
        self.credit = new_credit;
        // Iterations spent re-doing work lost to a node failure advance
        // the clock but not the loss stream: the job replays already-seen
        // iterations from its last checkpoint. With no pending restart
        // debt `redo` is 0 and the loop below is bit-identical to the
        // fault-free path.
        let redo = n.min(self.pending_restart_iters);
        self.pending_restart_iters -= redo;
        let n = n - redo;
        let mut done = 0;
        for i in 1..=n {
            self.iteration += 1;
            let t = t0 + iter_time * (redo + i) as f64 - credit0;
            let loss = self.source.loss_at(self.iteration);
            self.record(t, loss);
            done += 1;
            if self.check_converged(loss) || self.iteration >= self.spec.max_iterations {
                self.complete(t);
                break;
            }
        }
        done
    }

    fn record(&mut self, t: f64, loss: f64) {
        let prev = self.predictor.current_loss();
        self.predictor.observe(self.iteration, loss, t);
        self.loss_trace.push((t, self.iteration, loss));
        // Track stalls for the floorless convergence criterion.
        if let Some(prev) = prev {
            let rel = (prev - loss).abs() / prev.abs().max(1e-12);
            if rel < STALL_TOL {
                self.small_delta_streak += 1;
            } else {
                self.small_delta_streak = 0;
            }
        }
    }

    fn check_converged(&self, loss: f64) -> bool {
        match self.source.known_floor() {
            Some(floor) => {
                let span = self.initial_loss - floor;
                if span <= 0.0 {
                    return true;
                }
                let achieved = (self.initial_loss - loss) / span;
                achieved >= self.spec.target_fraction
            }
            None => self.small_delta_streak >= STALL_STREAK,
        }
    }

    fn complete(&mut self, t: f64) {
        self.state = JobState::Completed;
        self.completion_time = Some(t);
        self.cores = 0;
    }

    /// Latest observed loss (initial loss before any iteration).
    pub fn current_loss(&self) -> f64 {
        self.loss_trace.last().map(|s| s.2).unwrap_or(self.initial_loss)
    }

    /// Iterations this job could complete in a `window`-second epoch with
    /// `cores` cores, counting banked partial progress.
    pub fn iterations_achievable(&self, window: f64, cores: u32) -> u64 {
        if cores == 0 {
            return 0;
        }
        self.spec
            .cost
            .iterations_in_window(window, cores, self.credit)
            .0
    }

    /// Fractional iterations achievable in a `window`-second epoch with
    /// `cores` cores, on the *unscaled* clock (shared definition:
    /// [`CostModel::fractional_iterations`]). On multi-rack topologies
    /// the coordinator's gain views additionally apply the job's
    /// locality slowdown ([`CostModel::fractional_iterations_scaled`]);
    /// at one rack the two agree bit for bit.
    pub fn iterations_achievable_f(&self, window: f64, cores: u32) -> f64 {
        if cores == 0 {
            return 0.0;
        }
        self.spec.cost.fractional_iterations(window, cores, self.credit)
    }

    /// Serialize the complete job — spec, lifecycle state, predictor,
    /// loss-source descriptor, progress counters, full loss trace — for
    /// the durable-coordinator snapshot. Fails with `InvalidData` when the
    /// loss source is not serializable (no
    /// [`super::source::SourceDescriptor`]); durable coordinators reject
    /// such sources at submission already.
    pub fn encode_state(&self, e: &mut crate::util::codec::Enc) -> std::io::Result<()> {
        let descriptor = self.source.descriptor().ok_or_else(|| {
            crate::util::codec::corrupt(format!(
                "job {} has a non-serializable loss source",
                self.spec.id
            ))
        })?;
        self.spec.encode(e);
        e.put_u8(match self.state {
            JobState::Pending => 0,
            JobState::Running => 1,
            JobState::Completed => 2,
            JobState::Cancelled => 3,
        });
        self.predictor.encode_state(e);
        descriptor.encode(e);
        e.put_u64(self.iteration);
        e.put_f64(self.credit);
        e.put_u32(self.cores);
        e.put_u32(self.max_rack_span);
        e.put_f64(self.initial_loss);
        e.put_opt_f64(self.completion_time);
        e.put_usize(self.loss_trace.len());
        for &(t, it, loss) in &self.loss_trace {
            e.put_f64(t);
            e.put_u64(it);
            e.put_f64(loss);
        }
        e.put_u32(self.small_delta_streak);
        e.put_u64(self.ckpt_iteration);
        e.put_u64(self.pending_restart_iters);
        e.put_u32(self.elastic_applied);
        Ok(())
    }

    /// Inverse of [`Job::encode_state`]; the decoded job continues the
    /// original run bit for bit (predictor, source RNG and stall counter
    /// included).
    pub fn decode_state(d: &mut crate::util::codec::Dec) -> std::io::Result<Self> {
        use super::source::SourceDescriptor;
        use crate::util::codec::corrupt;
        let spec = JobSpec::decode(d)?;
        let state = match d.u8()? {
            0 => JobState::Pending,
            1 => JobState::Running,
            2 => JobState::Completed,
            3 => JobState::Cancelled,
            t => return Err(corrupt(format!("unknown job state {t}"))),
        };
        let predictor = OnlinePredictor::decode_state(d)?;
        let source = SourceDescriptor::decode(d)?.instantiate();
        let iteration = d.u64()?;
        let credit = d.f64()?;
        let cores = d.u32()?;
        let max_rack_span = d.u32()?;
        let initial_loss = d.f64()?;
        let completion_time = d.opt_f64()?;
        let n = d.usize_()?;
        let mut loss_trace = Vec::with_capacity(n.min(1 << 20));
        for _ in 0..n {
            loss_trace.push((d.f64()?, d.u64()?, d.f64()?));
        }
        let small_delta_streak = d.u32()?;
        let ckpt_iteration = d.u64()?;
        let pending_restart_iters = d.u64()?;
        let elastic_applied = d.u32()?;
        Ok(Self {
            spec,
            state,
            predictor,
            source,
            iteration,
            credit,
            cores,
            max_rack_span,
            initial_loss,
            completion_time,
            loss_trace,
            small_delta_streak,
            ckpt_iteration,
            pending_restart_iters,
            elastic_applied,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::source::SyntheticSource;
    use crate::predictor::CurveModel;
    use crate::util::rng::Rng;

    fn spec(id: u64) -> JobSpec {
        JobSpec {
            id,
            name: format!("job-{id}"),
            kind: CurveKind::Exponential,
            cost: CostModel::new(0.1, 2.0),
            max_cores: 16,
            arrival: 0.0,
            target_fraction: 0.95,
            max_iterations: 10_000,
            target_hint: None,
            elastic: Vec::new(),
        }
    }

    fn exp_job(id: u64) -> Job {
        let curve = CurveModel::Exponential { m: 4.0, mu: 0.8, c: 1.0 };
        Job::new(spec(id), Box::new(SyntheticSource::new(curve, 0.0, Rng::new(id))))
    }

    #[test]
    fn activation_reads_initial_loss() {
        let mut j = exp_job(1);
        j.activate(0.0);
        assert_eq!(j.state, JobState::Running);
        assert_eq!(j.initial_loss, 5.0);
        assert_eq!(j.loss_trace.len(), 1);
    }

    #[test]
    fn advance_completes_expected_iterations() {
        let mut j = exp_job(2);
        j.activate(0.0);
        // iter_time(4) = 0.1 + 2/4 = 0.6s; 3.1s window -> 5 iterations
        // with ~0.1s of leftover credit.
        let n = j.advance(0.0, 3.1, 4);
        assert_eq!(n, 5);
        assert_eq!(j.iteration, 5);
        assert!(j.credit >= 0.0 && j.credit < 0.6);
    }

    #[test]
    fn credit_carries_across_windows() {
        let mut j = exp_job(3);
        j.activate(0.0);
        let n1 = j.advance(0.0, 0.5, 1); // iter_time(1) = 2.1s -> 0 iterations
        assert_eq!(n1, 0);
        let n2 = j.advance(0.5, 2.0, 1); // credit 0.5 + 2.0 = 2.5 -> 1 iteration
        assert_eq!(n2, 1);
    }

    #[test]
    fn converges_at_target_fraction() {
        let mut j = exp_job(4);
        j.activate(0.0);
        // Run with generous resources until convergence.
        let mut t = 0.0;
        for _ in 0..200 {
            if j.state != JobState::Running {
                break;
            }
            j.advance(t, 3.0, 16);
            t += 3.0;
        }
        assert_eq!(j.state, JobState::Completed);
        // 95% of the way from 5.0 to 1.0 => loss <= 1.2
        assert!(j.current_loss() <= 1.2 + 1e-9);
        assert!(j.completion_time.is_some());
        assert_eq!(j.cores, 0, "completed job must hold no cores");
    }

    #[test]
    fn zero_cores_makes_no_progress() {
        let mut j = exp_job(5);
        j.activate(0.0);
        assert_eq!(j.advance(0.0, 10.0, 0), 0);
        assert_eq!(j.iteration, 0);
    }

    #[test]
    fn iteration_cap_completes_job() {
        let mut j = exp_job(6);
        j.spec.max_iterations = 3;
        j.activate(0.0);
        j.advance(0.0, 100.0, 16);
        assert_eq!(j.state, JobState::Completed);
        assert_eq!(j.iteration, 3);
    }

    #[test]
    fn floorless_source_converges_on_stall() {
        struct Flat;
        impl LossSource for Flat {
            fn loss_at(&mut self, it: u64) -> f64 {
                // quick decay then flat
                4.0 * 0.5f64.powf(it.min(6) as f64) + 1.0
            }
            fn known_floor(&self) -> Option<f64> {
                None
            }
        }
        let mut j = Job::new(spec(7), Box::new(Flat));
        j.activate(0.0);
        let mut t = 0.0;
        for _ in 0..100 {
            if j.state != JobState::Running {
                break;
            }
            j.advance(t, 3.0, 8);
            t += 3.0;
        }
        assert_eq!(j.state, JobState::Completed);
    }

    #[test]
    fn locality_slowdown_stretches_the_iteration_clock() {
        // iter_time(4) = 0.6s; at slowdown 2.0 each iteration takes 1.2s,
        // so a 3.1s window completes 2 instead of 5.
        let mut j = exp_job(9);
        j.activate(0.0);
        let n = j.advance_with_locality(0.0, 3.1, 4, 2.0);
        assert_eq!(n, 2);
        assert!(j.credit >= 0.0 && j.credit < 1.2);
        // A unit slowdown is bit-identical to the plain advance.
        let mut a = exp_job(10);
        let mut b = exp_job(10); // same seed: identical loss stream
        a.activate(0.0);
        b.activate(0.0);
        assert_eq!(
            a.advance(0.0, 3.1, 4),
            b.advance_with_locality(0.0, 3.1, 4, 1.0)
        );
        assert_eq!(a.credit, b.credit);
        assert_eq!(a.loss_trace, b.loss_trace);
    }

    #[test]
    fn restart_debt_consumes_window_time_without_advancing_loss() {
        // iter_time(4) = 0.6s; a 3.1s window fits 5 iteration slots. With
        // 2 iterations of restart debt, only 3 produce new samples and
        // the first new sample lands where slot 3 would have.
        let mut j = exp_job(11);
        j.activate(0.0);
        j.pending_restart_iters = 2;
        let n = j.advance(0.0, 3.1, 4);
        assert_eq!(n, 3);
        assert_eq!(j.iteration, 3);
        assert_eq!(j.pending_restart_iters, 0);
        assert_eq!(j.loss_trace.len(), 1 + 3);
        assert!((j.loss_trace[1].0 - 1.8).abs() < 1e-12, "first real iteration at slot 3");
    }

    #[test]
    fn restart_debt_larger_than_the_window_carries_over() {
        let mut j = exp_job(12);
        j.activate(0.0);
        j.pending_restart_iters = 7;
        let n = j.advance(0.0, 3.1, 4); // 5 slots, all redo
        assert_eq!(n, 0);
        assert_eq!(j.iteration, 0);
        assert_eq!(j.pending_restart_iters, 2);
        assert_eq!(j.loss_trace.len(), 1, "no new samples while replaying");
        // Zero debt is bit-identical to the plain path.
        let mut a = exp_job(13);
        let mut b = exp_job(13);
        a.activate(0.0);
        b.activate(0.0);
        b.pending_restart_iters = 0;
        assert_eq!(a.advance(0.0, 3.1, 4), b.advance(0.0, 3.1, 4));
        assert_eq!(a.credit.to_bits(), b.credit.to_bits());
        assert_eq!(a.loss_trace, b.loss_trace);
    }

    #[test]
    fn elastic_events_change_cap_and_work_scale_as_applied() {
        let mut j = exp_job(20);
        j.spec.elastic = vec![
            ElasticSpec { at_iteration: 5, max_cores: 32, work_scale: 2.0 },
            ElasticSpec { at_iteration: 9, max_cores: 4, work_scale: 0.5 },
        ];
        // Nothing applied: spec shape, unit scale, slowdown passes through
        // bitwise.
        assert_eq!(j.effective_max_cores(), 16);
        assert_eq!(j.work_scale(), 1.0);
        assert_eq!(j.work_scaled(1.7).to_bits(), 1.7f64.to_bits());
        // First event applied: wider cap, doubled work.
        j.elastic_applied = 1;
        assert_eq!(j.effective_max_cores(), 32);
        assert_eq!(j.work_scaled(1.5), 3.0);
        // Second event applied: late-phase shrink.
        j.elastic_applied = 2;
        assert_eq!(j.effective_max_cores(), 4);
        assert_eq!(j.work_scaled(2.0), 1.0);
    }

    #[test]
    fn elastic_spec_and_applied_counter_survive_the_state_codec() {
        let mut j = exp_job(21);
        j.spec.elastic =
            vec![ElasticSpec { at_iteration: 3, max_cores: 8, work_scale: 1.25 }];
        j.activate(0.0);
        j.advance(0.0, 3.1, 4);
        j.elastic_applied = 1;
        let mut e = crate::util::codec::Enc::new();
        j.encode_state(&mut e).unwrap();
        let mut d = crate::util::codec::Dec::new(e.bytes());
        let back = Job::decode_state(&mut d).unwrap();
        assert_eq!(back.spec.elastic, j.spec.elastic);
        assert_eq!(back.elastic_applied, 1);
        assert_eq!(back.effective_max_cores(), 8);
        assert_eq!(back.work_scale(), 1.25);
        assert_eq!(back.iteration, j.iteration);
        assert_eq!(back.loss_trace, j.loss_trace);
    }

    #[test]
    fn iterations_achievable_matches_cost_model() {
        let mut j = exp_job(8);
        j.activate(0.0);
        // iter_time(2) = 0.1 + 1.0 = 1.1
        assert_eq!(j.iterations_achievable(3.0, 2), 2);
        assert_eq!(j.iterations_achievable(3.0, 0), 0);
    }
}
