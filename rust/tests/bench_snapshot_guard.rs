//! Guard for the committed `BENCH_sched.json` snapshot at the repo root.
//!
//! The snapshot once shipped with an empty `entries` array — a run that
//! measured nothing clobbered the committed numbers and nobody noticed
//! until a dashboard went blank. `write_bench_json` now refuses to write
//! an empty list at the producer side; this test is the consumer-side
//! guard: the *committed* snapshot must either carry real entries or be
//! explicitly labeled as an unmeasured placeholder (`host` starting with
//! `UNMEASURED`), so a silent regression to a blank-but-plausible file
//! fails CI. The same rule covers the bench families a measured
//! snapshot must include: a run on the pinned machine emits the
//! `tournament_*` quality entries, the `chaos_*` fault-injection
//! counts and the `elastic_*` transition-pricing comparison alongside
//! the latency sweeps, so a measured snapshot without them is stale.

use std::path::Path;

/// Pull the string value of a top-level `"key": "value"` pair out of the
/// snapshot without a JSON dependency (the build is fully offline). Good
/// enough for the flat, machine-written file `write_bench_json` emits.
fn string_field(doc: &str, key: &str) -> Option<String> {
    let pat = format!("\"{key}\"");
    let rest = &doc[doc.find(&pat)? + pat.len()..];
    let rest = &rest[rest.find(':')? + 1..];
    let rest = &rest[rest.find('"')? + 1..];
    Some(rest[..rest.find('"')?].to_string())
}

fn snapshot() -> (String, &'static str) {
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_sched.json");
    (
        std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("BENCH_sched.json must stay committed at the repo root: {e}")),
        "BENCH_sched.json",
    )
}

#[test]
fn snapshot_parses_and_declares_the_v2_schema() {
    let (doc, name) = snapshot();
    assert_eq!(
        string_field(&doc, "schema").as_deref(),
        Some("slaq-bench-v2"),
        "{name} must declare the slaq-bench-v2 schema"
    );
    assert!(
        string_field(&doc, "command").is_some_and(|c| c.contains("cargo bench")),
        "{name} must record the command that produced it"
    );
    assert!(doc.contains("\"entries\""), "{name} lost its entries array");
}

#[test]
fn snapshot_entries_are_never_silently_empty() {
    let (doc, name) = snapshot();
    let entries_start = doc.find("\"entries\"").expect("entries array present");
    // Any real entry is an object; an empty array has no `{` after the key.
    let has_entries = doc[entries_start..].contains('{');
    if has_entries {
        // Real measurements: every entry must carry the full stat tuple.
        for field in ["\"name\"", "\"mean_secs\"", "\"p50_secs\"", "\"p95_secs\"", "\"iters\""] {
            assert!(
                doc[entries_start..].contains(field),
                "{name} entries are missing {field}"
            );
        }
    } else {
        // A blank snapshot is only acceptable when it says so out loud.
        let host = string_field(&doc, "host").unwrap_or_default();
        assert!(
            host.starts_with("UNMEASURED"),
            "{name} has an empty entries list but does not declare itself \
             UNMEASURED (host = {host:?}); regenerate it with \
             `cargo bench --bench sched_scalability` on the pinned machine \
             or restore the labeled placeholder"
        );
    }
}

#[test]
fn measured_snapshots_carry_the_tournament_family() {
    let (doc, name) = snapshot();
    let host = string_field(&doc, "host").unwrap_or_default();
    if host.starts_with("UNMEASURED") {
        // Labeled placeholder: no entries of any family expected; the
        // empty-list rule above already polices it.
        return;
    }
    // A measured run of `cargo bench --bench sched_scalability` emits the
    // tournament quality entries unconditionally, so a measured snapshot
    // that lacks them predates the policy tournament and must be
    // regenerated.
    assert!(
        doc.contains("\"name\":\"tournament_"),
        "{name} was measured (host = {host:?}) but carries no tournament_* \
         entries; regenerate it with `cargo bench --bench sched_scalability` \
         on the pinned machine"
    );
}

#[test]
fn measured_snapshots_carry_the_chaos_family() {
    let (doc, name) = snapshot();
    let host = string_field(&doc, "host").unwrap_or_default();
    if host.starts_with("UNMEASURED") {
        return;
    }
    // A measured run emits the chaos fault-injection counts
    // unconditionally; a measured snapshot that lacks them predates the
    // chaos-hardened scheduler and must be regenerated.
    assert!(
        doc.contains("\"name\":\"chaos_"),
        "{name} was measured (host = {host:?}) but carries no chaos_* \
         entries; regenerate it with `cargo bench --bench sched_scalability` \
         on the pinned machine"
    );
}

#[test]
fn measured_snapshots_carry_the_elastic_family() {
    let (doc, name) = snapshot();
    let host = string_field(&doc, "host").unwrap_or_default();
    if host.starts_with("UNMEASURED") {
        return;
    }
    // A measured run emits the elastic transition-pricing comparison
    // unconditionally; a measured snapshot that lacks it predates
    // checkpoint-aware reallocation pricing and must be regenerated.
    assert!(
        doc.contains("\"name\":\"elastic_"),
        "{name} was measured (host = {host:?}) but carries no elastic_* \
         entries; regenerate it with `cargo bench --bench sched_scalability` \
         on the pinned machine"
    );
}
