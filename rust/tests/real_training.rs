//! Integration: the full Rust → PJRT → AOT-artifact training path.
//!
//! Requires `make artifacts` to have run (the Makefile's `test` target
//! guarantees the order); tests skip with a message otherwise.

use slaq::mltrain::{AlgoKind, ExecSource, TrainSession, ALL_ALGOS};
use slaq::coordinator::LossSource;
use slaq::runtime::{Manifest, Runtime, RuntimeConfig};
use std::path::Path;

fn runtime() -> Option<(Runtime, Manifest)> {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts/manifest.json missing; run `make artifacts`");
        return None;
    }
    let rt = Runtime::cpu(RuntimeConfig { artifact_dir: dir.to_path_buf() }).unwrap();
    let manifest = Manifest::load(dir).unwrap();
    Some((rt, manifest))
}

#[test]
fn every_algorithm_trains_and_improves() {
    let Some((rt, manifest)) = runtime() else { return };
    for algo in ALL_ALGOS {
        let mut sess = TrainSession::new(&rt, &manifest, "small", algo, 7).unwrap();
        let mut losses = Vec::new();
        for _ in 0..20 {
            losses.push(sess.step().unwrap());
        }
        assert!(
            losses.iter().all(|l| l.is_finite()),
            "{algo:?}: non-finite loss {losses:?}"
        );
        let first = losses[0];
        let last = *losses.last().unwrap();
        assert!(
            last < first,
            "{algo:?}: loss did not improve ({first} -> {last})"
        );
    }
}

#[test]
fn kmeans_loss_is_monotone_nonincreasing() {
    let Some((rt, manifest)) = runtime() else { return };
    let mut sess = TrainSession::new(&rt, &manifest, "small", AlgoKind::Kmeans, 3).unwrap();
    let mut prev = f64::INFINITY;
    for _ in 0..15 {
        let loss = sess.step().unwrap();
        assert!(loss <= prev + 1e-5, "Lloyd iteration increased loss");
        prev = loss;
    }
}

#[test]
fn newton_converges_in_few_iterations() {
    let Some((rt, manifest)) = runtime() else { return };
    let mut sess =
        TrainSession::new(&rt, &manifest, "small", AlgoKind::NewtonLogreg, 11).unwrap();
    let mut losses = Vec::new();
    for _ in 0..8 {
        losses.push(sess.step().unwrap());
    }
    let tail_delta = (losses[6] - losses[7]).abs() / losses[0];
    assert!(tail_delta < 1e-3, "Newton should flatline: {losses:?}");
    assert!(losses[7] < 0.7 * losses[0]);
}

#[test]
fn exec_source_feeds_coordinator_losses() {
    let Some((rt, manifest)) = runtime() else { return };
    let sess = TrainSession::new(&rt, &manifest, "small", AlgoKind::LogregGd, 5).unwrap();
    let mut src = ExecSource::new(sess);
    let l0 = src.loss_at(0);
    let l5 = src.loss_at(5);
    // Querying out of order within the cache is fine.
    let l3 = src.loss_at(3);
    assert!(l5 < l0);
    assert!(l3 <= l0 && l3 >= l5 - 1e-9);
    assert_eq!(src.losses().len(), 6);
    assert_eq!(src.known_floor(), None);
}

#[test]
fn sessions_are_deterministic_from_seed() {
    let Some((rt, manifest)) = runtime() else { return };
    let mut a = TrainSession::new(&rt, &manifest, "small", AlgoKind::SvmGd, 42).unwrap();
    let mut b = TrainSession::new(&rt, &manifest, "small", AlgoKind::SvmGd, 42).unwrap();
    for _ in 0..5 {
        assert_eq!(a.step().unwrap(), b.step().unwrap());
    }
    let pa = a.params_f32().unwrap();
    let pb = b.params_f32().unwrap();
    assert_eq!(pa, pb);
}

#[test]
fn slaq_coordinator_schedules_real_jobs_end_to_end() {
    // Miniature of examples/quickstart.rs as a regression gate: real AOT
    // training steps driven by the SLAQ epoch loop.
    use slaq::cluster::{ClusterSpec, CostModel};
    use slaq::coordinator::{Coordinator, CoordinatorConfig, JobSpec};
    use slaq::sched::SlaqPolicy;

    let Some((rt, manifest)) = runtime() else { return };
    let cfg = CoordinatorConfig {
        cluster: ClusterSpec { nodes: 1, cores_per_node: 8 },
        epoch_secs: 2.0,
        ..Default::default()
    };
    let mut coord = Coordinator::new(cfg, Box::new(SlaqPolicy::new()));
    for (i, algo) in [AlgoKind::LogregGd, AlgoKind::Kmeans, AlgoKind::NewtonLogreg]
        .iter()
        .enumerate()
    {
        let sess = TrainSession::new(&rt, &manifest, "small", *algo, 50 + i as u64).unwrap();
        coord.submit(
            JobSpec {
                id: i as u64,
                name: algo.model_name().to_string(),
                kind: algo.curve_kind(),
                cost: CostModel::new(0.05, 4.0),
                max_cores: 8,
                arrival: 2.0 * i as f64,
                target_fraction: 0.95,
                max_iterations: 120,
                target_hint: None,
                elastic: Vec::new(),
            },
            Box::new(ExecSource::new(sess)),
        );
    }
    coord.run_to_completion(2000);
    let (pending, running, done) = coord.job_counts();
    assert_eq!((pending, running, done), (0, 0, 3));
    let trace = coord.into_trace();
    for j in &trace.jobs {
        let last = j.samples.last().unwrap().2;
        assert!(last < j.initial_loss, "{} did not improve", j.name);
        assert!(j.completion.is_some());
    }
    // The JSON dump of a real-execution trace must be valid JSON.
    let dump = trace.to_json().to_string();
    assert!(slaq::util::json::parse(&dump).is_ok());
}

#[test]
fn base_variant_also_loads() {
    let Some((rt, manifest)) = runtime() else { return };
    let mut sess = TrainSession::new(&rt, &manifest, "base", AlgoKind::LinregGd, 1).unwrap();
    let l0 = sess.step().unwrap();
    let l1 = sess.step().unwrap();
    assert!(l1 < l0);
}
