//! Shared micro-benchmark harness (criterion is not available in the
//! offline build; this reproduces the part we need: warmup, repeated
//! timing, robust summary statistics, and a machine-readable JSON dump).

use std::time::Instant;

/// Summary statistics of one benchmark (seconds per iteration).
///
/// A few bench binaries reuse the mean/p50/p95 fields for unit-less
/// *counts* instead of latencies; such entries always carry an explicit
/// `_per_epoch` name suffix so latency dashboards can filter them out.
#[allow(dead_code)] // shared across bench binaries; not all use every item
#[derive(Debug, Clone)]
pub struct BenchStats {
    /// Benchmark name.
    pub name: String,
    /// Mean latency (seconds).
    pub mean: f64,
    /// Median latency (seconds).
    pub p50: f64,
    /// 95th-percentile latency (seconds).
    pub p95: f64,
    /// Timed iterations.
    pub iters: usize,
}

impl BenchStats {
    /// Render as one JSON object (flat, all-numeric fields).
    #[allow(dead_code)]
    pub fn to_json(&self) -> String {
        format!(
            "{{\"name\":\"{}\",\"mean_secs\":{:e},\"p50_secs\":{:e},\"p95_secs\":{:e},\"iters\":{}}}",
            self.name, self.mean, self.p50, self.p95, self.iters
        )
    }
}

/// Time `f` for `iters` iterations after `warmup` warmup calls; prints
/// mean / p50 / p95 per-iteration latency and returns the statistics.
#[allow(dead_code)]
pub fn bench_stats<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let p50 = samples[samples.len() / 2];
    let p95 = samples[(samples.len() * 95 / 100).min(samples.len() - 1)];
    println!(
        "{name:<44} mean {:>10} p50 {:>10} p95 {:>10} (n={iters})",
        fmt(mean),
        fmt(p50),
        fmt(p95)
    );
    BenchStats { name: name.to_string(), mean, p50, p95, iters }
}

/// [`bench_stats`] without the return value (most benches only print).
#[allow(dead_code)]
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, f: F) {
    let _ = bench_stats(name, warmup, iters, f);
}

/// Write benchmark statistics to `path` as a JSON object:
/// `{schema, host, command, entries: [...]}`. The metadata header is what
/// makes a committed snapshot auditable — it records which machine and
/// command produced the numbers, so PR-over-PR comparisons only trust
/// matching hosts.
///
/// Refuses (with `InvalidInput`) to write an empty `entries` list: a run
/// that measured nothing must never clobber a committed snapshot with a
/// blank file — exactly the accident that shipped an empty
/// `BENCH_sched.json` once.
#[allow(dead_code)]
pub fn write_bench_json(path: &str, command: &str, stats: &[BenchStats]) -> std::io::Result<()> {
    if stats.is_empty() {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidInput,
            format!("refusing to overwrite {path} with an empty entries list"),
        ));
    }
    let body: Vec<String> = stats.iter().map(|s| format!("    {}", s.to_json())).collect();
    let host = format!(
        "{}-{} x{}",
        std::env::consts::OS,
        std::env::consts::ARCH,
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(0)
    );
    std::fs::write(
        path,
        format!(
            "{{\n  \"schema\": \"slaq-bench-v2\",\n  \"host\": \"{host}\",\n  \
             \"command\": \"{command}\",\n  \"entries\": [\n{}\n  ]\n}}\n",
            body.join(",\n")
        ),
    )
}

fn fmt(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3} s")
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else {
        format!("{:.1} µs", secs * 1e6)
    }
}

/// Prevent the optimizer from discarding a value.
#[allow(dead_code)] // not every bench needs it
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}
