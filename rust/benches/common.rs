//! Shared micro-benchmark harness (criterion is not available in the
//! offline build; this reproduces the part we need: warmup, repeated
//! timing, and robust summary statistics).

use std::time::Instant;

/// Time `f` for `iters` iterations after `warmup` warmup calls; prints
/// mean / p50 / p95 per-iteration latency.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let p50 = samples[samples.len() / 2];
    let p95 = samples[(samples.len() * 95 / 100).min(samples.len() - 1)];
    println!(
        "{name:<44} mean {:>10} p50 {:>10} p95 {:>10} (n={iters})",
        fmt(mean),
        fmt(p50),
        fmt(p95)
    );
}

fn fmt(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3} s")
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else {
        format!("{:.1} µs", secs * 1e6)
    }
}

/// Prevent the optimizer from discarding a value.
#[allow(dead_code)] // not every bench needs it
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}
