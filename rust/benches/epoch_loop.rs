//! Bench: full coordinator epochs — the end-to-end scheduling path
//! (activate → gain oracles → allocate → place → advance → trace) on the
//! paper-scale simulated cluster.

#[path = "common.rs"]
mod common;

use common::bench;
use slaq::cluster::ClusterSpec;
use slaq::coordinator::{Coordinator, CoordinatorConfig};
use slaq::sched::policy_by_name;
use slaq::util::rng::Rng;
use slaq::workload::{paper_trace, TraceConfig};

fn build(jobs: usize, policy: &str) -> Coordinator {
    let cfg = CoordinatorConfig {
        cluster: ClusterSpec::paper_testbed(),
        epoch_secs: 3.0,
        ..Default::default()
    };
    let mut coord = Coordinator::new(cfg, policy_by_name(policy).unwrap());
    let mut rng = Rng::new(0xBEEF);
    for mut t in paper_trace(&TraceConfig {
        jobs,
        mean_interarrival: 0.1, // all active almost immediately
        seed: 7,
    }) {
        t.spec.arrival = 0.0;
        let src = t.make_source(&mut rng);
        coord.submit(t.spec, src);
    }
    // Warm up: activate everyone and accumulate history for the fits.
    coord.run_until(30.0);
    coord
}

fn main() {
    for policy in ["slaq", "fair"] {
        for jobs in [40usize, 160, 640] {
            let mut coord = build(jobs, policy);
            bench(&format!("epoch_{policy}_{jobs}_jobs"), 2, 50, || {
                coord.step_epoch();
            });
        }
    }
}
