//! Bench: online predictor — curve fitting and gain evaluation, the two
//! per-epoch costs of the SLAQ coordinator (fits happen per completed
//! iteration; gain evaluations per allocation step).

#[path = "common.rs"]
mod common;

use common::{bench, black_box};
use slaq::predictor::{fit_history, CurveKind, FitConfig, OnlinePredictor};
use slaq::quality::LossHistory;
use slaq::util::rng::Rng;

fn history(n: u64, kind: CurveKind, rng: &mut Rng) -> LossHistory {
    let mut h = LossHistory::new();
    for k in 0..n {
        let kf = k as f64;
        let clean = match kind {
            CurveKind::Sublinear => 1.0 / (0.1 * kf + 0.5) + 0.2,
            CurveKind::Exponential => 4.0 * 0.9f64.powf(kf) + 0.5,
        };
        h.push(k, clean * (1.0 + 0.005 * rng.normal()), kf);
    }
    h
}

fn main() {
    let cfg = FitConfig::default();
    let mut rng = Rng::new(3);
    for kind in [CurveKind::Sublinear, CurveKind::Exponential] {
        for n in [16u64, 64, 256] {
            let h = history(n, kind, &mut rng);
            bench(&format!("fit_{kind:?}_{n}_samples"), 5, 200, || {
                black_box(fit_history(&h, kind, &cfg));
            });
        }
    }

    // Gain-oracle evaluation (the inner loop of Fig 6).
    let mut pred = OnlinePredictor::new(CurveKind::Exponential);
    for k in 0..64u64 {
        pred.observe(k, 4.0 * 0.9f64.powf(k as f64) + 0.5, k as f64);
    }
    bench("predicted_normalized_reduction", 100, 10_000, || {
        black_box(pred.predicted_normalized_reduction(2.5));
    });

    // Full observe (fit refresh included) — per-iteration coordinator cost.
    bench("observe_with_refit_64_window", 5, 500, || {
        let mut p = OnlinePredictor::new(CurveKind::Exponential);
        for k in 0..64u64 {
            p.observe(k, 4.0 * 0.9f64.powf(k as f64) + 0.5, k as f64);
        }
        black_box(p.current_loss());
    });
}
