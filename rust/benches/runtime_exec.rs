//! Bench: PJRT step-execution latency per algorithm — the runtime overhead
//! (literal upload + execute + tuple decode) that real-mode training pays
//! per BSP iteration, for both artifact shape variants.

#[path = "common.rs"]
mod common;

use common::bench;
use slaq::mltrain::{TrainSession, ALL_ALGOS};
use slaq::runtime::{Manifest, Runtime, RuntimeConfig};
use std::path::Path;

fn main() {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    let rt = Runtime::cpu(RuntimeConfig::default()).unwrap();
    let manifest = Manifest::load(dir).unwrap();
    for variant in ["small", "base"] {
        println!("== variant {variant} ==");
        for algo in ALL_ALGOS {
            let mut sess = TrainSession::new(&rt, &manifest, variant, algo, 1).unwrap();
            bench(&format!("step_{}_{variant}", algo.model_name()), 3, 30, || {
                sess.step().unwrap();
            });
        }
    }
}
