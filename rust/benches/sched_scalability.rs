//! Bench: Fig 6 — SLAQ allocation decision time at scale, the jobs×cores
//! sweep the paper plots, the churn scenario comparing the incremental
//! (warm-start) decision path against from-scratch, and the end-to-end
//! coordinator epoch loop under the same churn regime at 1000–100 000
//! jobs (the top cell via the sharded coordinator).
//!
//! Besides the human-readable tables, the run emits `BENCH_sched.json` —
//! `{schema, host, command, entries}` where `entries` is an array of
//! `{name, mean_secs, p50_secs, p95_secs, iters}` objects — so CI and
//! plotting scripts can track decision latency, and a snapshot from the
//! pinned machine is committed at the repo root for PR-over-PR
//! comparison. The `epoch_loop_*` entries are whole-epoch latencies
//! (ledger activation, selective predictor refits, gain-table builds,
//! allocation, placement diffs, job advancement) on the machine's full
//! parallelism; `epoch_loop_*_t{N}` entries sweep the worker-thread knob
//! at the 4000-job cell (t1 = the serial reference path); the `churn_*`
//! entries are the allocation kernel alone. The split entries:
//! `epoch_loop_refit_*` is the predictor-sync latency inside each epoch,
//! `epoch_loop_gain_*` the materialized gain-table build (zero at t1),
//! and `epoch_loop_refits_per_epoch_*` reports *counts* (refits and
//! dirty jobs per epoch, in the mean/p50 fields) — with selective sync
//! these track jobs-with-new-samples, not the active-job count. The
//! `epoch_loop_sched_*` entries isolate the allocation-decision split
//! (the latency the sharded coordinator drives sub-millisecond).
//! `_s{N}` entries run the sharded coordinator (N zone shards, each with
//! its own warm-start/gain-table/CELF allocator, budgets rebalanced by
//! the broker every 8 epochs) — the configuration that scales the sweep
//! to the 100 000-job cell. The `placement_*_per_epoch_*` entries are
//! the locality scenario's placement-quality counts: mean rack span and
//! cross-rack cores moved per epoch, rack-aware vs rack-blind on a
//! 16-rack topology. The `tournament_{cell}_{policy}_per_epoch` entries
//! are the policy tournament's quality scores (counts, not latencies:
//! mean = mean normalized loss, p50 = Jain quality-fairness index,
//! p95 = mean seconds to 90% loss reduction or -1 when no job reached
//! it, iters = jobs that reached 90%) for all six schedulers across the
//! churny / contention / hetero-targets workload cells. The
//! `chaos_p{N}_per_epoch` entries are the fault-injection sweep's counts
//! (mean = cores lost to node failures, p50 = successful re-placements,
//! p95 = epochs with a failed re-placement, iters = jobs completed on
//! the surviving capacity) at N% per-node, per-epoch failure
//! probability; every chaos cell is audited (pool invariants per epoch,
//! bitwise run-to-run determinism) before it is published. The
//! `elastic_{aggressive,priced}_per_epoch` entries compare planning
//! blind against pricing the restart debt on the same elastic workload
//! under the same non-free transition model (mean = mean normalized
//! loss, p50 = voluntary restarts charged, p95 = mean seconds to 90%
//! reduction or -1, iters = jobs completed).

#[path = "common.rs"]
mod common;

use common::{bench_stats, write_bench_json, BenchStats};
use slaq::exp::{
    chaos_cell, churn_decision_cost, elastic_cell, epoch_loop_cost, fig6_sched_time,
    locality_cost, run_tournament, ChurnConfig, EpochLoopConfig, LocalityConfig,
    TournamentConfig, FAIL_PROBS,
};
use slaq::sched::{JobRequest, Policy, SlaqPolicy};
use slaq::util::rng::Rng;
use slaq::workload::SyntheticGain;

fn main() {
    let mut all: Vec<BenchStats> = Vec::new();

    println!("== Fig 6: full sweep (1000-4000 jobs × 4k-16k cores) ==");
    let out = fig6_sched_time(5);
    println!("{}", out.summary);

    println!("== single-cell latency distribution ==");
    let mut rng = Rng::new(1);
    for (jobs, cores) in [(1000usize, 4096u32), (4000, 16384)] {
        let gains: Vec<SyntheticGain> = (0..jobs)
            .map(|_| SyntheticGain {
                scale: rng.range_f64(0.01, 2.0),
                rate: rng.range_f64(0.02, 0.5),
            })
            .collect();
        let caps: Vec<u32> = (0..jobs).map(|_| rng.range_u64(32, 129) as u32).collect();
        let requests: Vec<JobRequest<'_>> = gains
            .iter()
            .enumerate()
            .map(|(i, g)| JobRequest { id: i as u64, max_cores: caps[i], prev_cores: 0, gain: g })
            .collect();
        let mut policy = SlaqPolicy::new();
        all.push(bench_stats(&format!("slaq_allocate_{jobs}x{cores}"), 2, 20, || {
            common::black_box(policy.allocate(&requests, cores));
        }));
    }

    println!("== churn: incremental vs from-scratch steady-state epochs ==");
    for (jobs, cores, churn) in [(1000usize, 4096u32, 16usize), (4000, 16384, 32)] {
        let cfg = ChurnConfig { jobs, cores, churn_per_epoch: churn, epochs: 10, seed: 7 };
        let scratch = churn_decision_cost(&cfg, false);
        let warm = churn_decision_cost(&cfg, true);
        let speedup = scratch.mean_millis() / warm.mean_millis().max(1e-9);
        println!(
            "churn_{jobs}x{cores}_r{churn}: scratch {:.2} ms/epoch ({:.0} evals) vs \
             incremental {:.2} ms/epoch ({:.0} evals) — {speedup:.1}x, warm {}/{}",
            scratch.mean_millis(),
            scratch.mean_evals(),
            warm.mean_millis(),
            warm.mean_evals(),
            warm.warm_epochs,
            warm.epochs,
        );
        for (mode, cost) in [("scratch", &scratch), ("incremental", &warm)] {
            all.push(BenchStats {
                name: format!("churn_{mode}_{jobs}x{cores}_r{churn}"),
                mean: cost.mean_millis() / 1e3,
                p50: cost.percentile_millis(50.0) / 1e3,
                p95: cost.percentile_millis(95.0) / 1e3,
                iters: cost.epochs,
            });
        }
    }

    println!("== churn: end-to-end coordinator epochs (full decision loop) ==");
    // Publish one entry set per cell at the machine's full parallelism
    // (threads: 0) — the headline configuration — plus the refit / gain /
    // count splits.
    let epoch_cell = |all: &mut Vec<BenchStats>, jobs: usize, cores: u32, churn: usize, threads: usize, shards: u32, suffix: &str| {
        let cfg = EpochLoopConfig {
            jobs,
            cores,
            churn_per_epoch: churn,
            epochs: 10,
            warmup_epochs: 3,
            seed: 7,
            refit_amortization: false,
            threads,
            shards,
            broker_epochs: 8,
        };
        let cost = epoch_loop_cost(&cfg);
        println!(
            "epoch_loop_{jobs}x{cores}_r{churn}{suffix}: epoch mean {:.2} ms (p50 {:.2}, \
             p95 {:.2}), allocation {:.3} ms (p95 {:.3}), refit {:.2} ms, gain build \
             {:.2} ms ({:.0} refits / {:.0} dirty / {:.0} active), {} completed / {} arrived",
            cost.mean_millis(),
            cost.percentile_millis(50.0),
            cost.percentile_millis(95.0),
            cost.mean_sched_millis(),
            cost.sched_percentile_millis(95.0),
            cost.mean_refit_millis(),
            cost.mean_gain_millis(),
            cost.mean_refits(),
            cost.mean_dirty(),
            cost.mean_active,
            cost.completed,
            cost.arrived,
        );
        all.push(BenchStats {
            name: format!("epoch_loop_{jobs}x{cores}_r{churn}{suffix}"),
            mean: cost.mean_millis() / 1e3,
            p50: cost.percentile_millis(50.0) / 1e3,
            p95: cost.percentile_millis(95.0) / 1e3,
            iters: cost.epoch_millis.len(),
        });
        // The allocation-decision split alone — the latency the sharded
        // coordinator is built to hold sub-millisecond at 100k jobs.
        all.push(BenchStats {
            name: format!("epoch_loop_sched_{jobs}x{cores}_r{churn}{suffix}"),
            mean: cost.mean_sched_millis() / 1e3,
            p50: cost.sched_percentile_millis(50.0) / 1e3,
            p95: cost.sched_percentile_millis(95.0) / 1e3,
            iters: cost.epoch_millis.len(),
        });
        // The epoch's three-way cost split: predictor-sync latency…
        all.push(BenchStats {
            name: format!("epoch_loop_refit_{jobs}x{cores}_r{churn}{suffix}"),
            mean: cost.mean_refit_millis() / 1e3,
            p50: cost.refit_percentile_millis(50.0) / 1e3,
            p95: cost.refit_percentile_millis(95.0) / 1e3,
            iters: cost.epoch_millis.len(),
        });
        // …the materialized gain-table build (zero on the t1 serial
        // reference path)…
        all.push(BenchStats {
            name: format!("epoch_loop_gain_{jobs}x{cores}_r{churn}{suffix}"),
            mean: cost.mean_gain_millis() / 1e3,
            p50: cost.gain_percentile_millis(50.0) / 1e3,
            p95: cost.gain_percentile_millis(95.0) / 1e3,
            iters: cost.epoch_millis.len(),
        });
        // …and the refit *counts* (mean = refits/epoch, p50 = dirty
        // jobs/epoch, p95 = mean active) — the acceptance metric that
        // refits track jobs-with-new-samples, not population size. The
        // `_per_epoch` suffix marks the entry as counts, not latencies
        // (see benches/common.rs).
        all.push(BenchStats {
            name: format!("epoch_loop_refits_per_epoch_{jobs}x{cores}_r{churn}{suffix}"),
            mean: cost.mean_refits(),
            p50: cost.mean_dirty(),
            p95: cost.mean_active,
            iters: cost.epoch_millis.len(),
        });
        cost
    };

    for (jobs, cores, churn) in [
        (1000usize, 4096u32, 16usize),
        (2000, 8192, 24),
        (4000, 16384, 32),
        (8000, 32768, 48),
        (16000, 65536, 64),
    ] {
        epoch_cell(&mut all, jobs, cores, churn, 0, 0, "");
    }

    println!("== churn: worker-thread sweep at the 4000-job cell ==");
    // t1 is the serial reference path (oracle calls in the allocator, no
    // tables, no workers); tN shards the refits and gain-table builds.
    // Results are identical — only wall-clock moves.
    let mut reference_cell: Option<slaq::exp::EpochLoopCost> = None;
    for threads in [1usize, 2, 4, 8] {
        let cost = epoch_cell(&mut all, 4000, 16384, 32, threads, 0, &format!("_t{threads}"));
        if threads == 1 {
            reference_cell = Some(cost);
        }
    }

    println!("== churn: sharded coordinator at scale (8 zone shards) ==");
    // The per-zone shard allocators + budget broker vs the flat path at
    // the top of the flat sweep, then the 100k-job cell the flat
    // coordinator cannot hold — the `epoch_loop_sched_*_s8` p95 is the
    // sub-millisecond acceptance target.
    for (jobs, cores, churn) in [(16000usize, 65536u32, 64usize), (100_000, 65536, 128)] {
        epoch_cell(&mut all, jobs, cores, churn, 0, 8, "_s8");
    }

    println!("== locality: rack-aware vs rack-blind placement (2×8 racks) ==");
    // Placement-quality cells: mean rack span per epoch (counts, not
    // latencies — hence the `_per_epoch` suffix; mean = mean-of-epoch-
    // means, p50/p95 = percentiles of the per-epoch mean span), plus the
    // cross-rack cores moved per epoch.
    for (jobs, cores, churn) in [(4000usize, 16384u32, 32usize), (8000, 32768, 48)] {
        let cfg = LocalityConfig {
            jobs,
            cores,
            zones: 2,
            racks_per_zone: 8,
            churn_per_epoch: churn,
            epochs: 10,
            warmup_epochs: 3,
            seed: 7,
            threads: 0,
        };
        for (mode, aware) in [("aware", true), ("blind", false)] {
            let cost = locality_cost(&cfg, aware);
            println!(
                "placement_{mode}_{jobs}x{cores}: mean span {:.3} (p95 {:.3}), \
                 {:.1} cross-rack cores/epoch, {} completed, conserving: {}",
                cost.mean_mean_span(),
                cost.span_percentile(95.0),
                cost.mean_cross_rack(),
                cost.completed,
                cost.work_conserving(),
            );
            all.push(BenchStats {
                name: format!("placement_span_per_epoch_{mode}_{jobs}x{cores}"),
                mean: cost.mean_mean_span(),
                p50: cost.span_percentile(50.0),
                p95: cost.span_percentile(95.0),
                iters: cost.epochs,
            });
            all.push(BenchStats {
                name: format!("placement_cross_rack_per_epoch_{mode}_{jobs}x{cores}"),
                mean: cost.mean_cross_rack(),
                p50: slaq::util::stats::percentile(&cost.cross_rack, 50.0),
                p95: slaq::util::stats::percentile(&cost.cross_rack, 95.0),
                iters: cost.epochs,
            });
        }
    }

    println!("== churn: refit amortization at the 4000-job cell ==");
    {
        // Compare against the serial (t1) run measured just above — the
        // amortization knob is orthogonal to the thread sweep.
        let exact = reference_cell.expect("4000-job t1 cell measured above");
        let amortized = epoch_loop_cost(&EpochLoopConfig {
            jobs: 4000,
            cores: 16384,
            churn_per_epoch: 32,
            epochs: 10,
            warmup_epochs: 3,
            seed: 7,
            refit_amortization: true,
            threads: 1,
            shards: 0,
            broker_epochs: 8,
        });
        println!(
            "epoch_loop_amortized_4000x16384_r32: refit {:.2} ms -> {:.2} ms, \
             refits/epoch {:.0} -> {:.0}",
            exact.mean_refit_millis(),
            amortized.mean_refit_millis(),
            exact.mean_refits(),
            amortized.mean_refits(),
        );
        all.push(BenchStats {
            name: "epoch_loop_refit_amortized_4000x16384_r32".to_string(),
            mean: amortized.mean_refit_millis() / 1e3,
            p50: amortized.refit_percentile_millis(50.0) / 1e3,
            p95: amortized.refit_percentile_millis(95.0) / 1e3,
            iters: amortized.epoch_millis.len(),
        });
    }

    println!("== policy tournament: quality scores across the cell grid ==");
    // Quality (not latency) cells — six policies × three workload cells,
    // with the per-epoch allocator invariants asserted before anything is
    // published. `_per_epoch` marks the entries as unit-less scores (see
    // benches/common.rs); time-to-90 is a simulated-seconds mean, mapped
    // to -1 when no job in the run reached 90% reduction (JSON has no
    // NaN).
    {
        let report = run_tournament(&TournamentConfig::default());
        report.assert_ok();
        for s in &report.scores {
            println!(
                "tournament_{}_{}: norm loss {:.4}, t90 {:.1}s ({} jobs), jain {:.3}",
                s.cell, s.policy, s.mean_norm_loss, s.time_to_90, s.reached_90, s.quality_fairness,
            );
            all.push(BenchStats {
                name: format!("tournament_{}_{}_per_epoch", s.cell, s.policy),
                mean: s.mean_norm_loss,
                p50: s.quality_fairness,
                p95: if s.time_to_90.is_finite() { s.time_to_90 } else { -1.0 },
                iters: s.reached_90,
            });
        }
    }

    println!("== chaos: fault-injection counts across failure rates ==");
    // Robustness (not latency) cells — every cell runs the audited
    // chaos sweep (pool invariants after each epoch, bitwise run-to-run
    // determinism, zero-rate inertness) before its counts are published.
    // `_per_epoch` marks the entries as counts (see benches/common.rs).
    for &p in &FAIL_PROBS {
        let cell = chaos_cell(0, false, p, 2, 7);
        println!(
            "chaos_p{:.0}: {} lost cores, {} replacements, {} failed epochs, \
             {} degraded transitions, {}/{} completed",
            p * 100.0,
            cell.lost_cores,
            cell.replacements,
            cell.failed_epochs,
            cell.degraded_transitions,
            cell.completed,
            cell.jobs,
        );
        all.push(BenchStats {
            name: format!("chaos_p{:.0}_per_epoch", p * 100.0),
            mean: cell.lost_cores as f64,
            p50: cell.replacements as f64,
            p95: cell.failed_epochs as f64,
            iters: cell.completed,
        });
    }

    println!("== elastic: aggressive vs hysteretic reallocation under priced transitions ==");
    // Quality (not latency) cells — both arms run the same seeded
    // elastic workload under the same non-free transition model; each
    // run is bitwise-deterministic and trial 0 re-proves zero-cost
    // inertness. mean = mean normalized loss, p50 = voluntary restarts
    // charged, p95 = mean seconds to 90% reduction (-1 when no job
    // reached it), iters = jobs completed.
    {
        let cell = elastic_cell(0, false, 0, 7);
        for (arm, stats) in [("aggressive", &cell.aggressive), ("priced", &cell.priced)] {
            println!(
                "elastic_{arm}: {} restarts, {:.4} mean norm loss, {:.2} t90, \
                 {}/{} completed",
                stats.voluntary_restarts,
                stats.mean_loss(),
                stats.mean_t90(),
                stats.completed,
                stats.jobs,
            );
            all.push(BenchStats {
                name: format!("elastic_{arm}_per_epoch"),
                mean: stats.mean_loss(),
                p50: stats.voluntary_restarts as f64,
                p95: if stats.reached > 0 { stats.mean_t90() } else { -1.0 },
                iters: stats.completed,
            });
        }
    }

    match write_bench_json("BENCH_sched.json", "cargo bench --bench sched_scalability", &all) {
        Ok(()) => println!("\nwrote BENCH_sched.json ({} entries)", all.len()),
        Err(e) => eprintln!("could not write BENCH_sched.json: {e}"),
    }
}
