//! Bench: Fig 6 — SLAQ allocation decision time at scale, plus the
//! jobs×cores sweep the paper plots.

#[path = "common.rs"]
mod common;

use common::bench;
use slaq::exp::fig6_sched_time;
use slaq::sched::{JobRequest, Policy, SlaqPolicy};
use slaq::util::rng::Rng;
use slaq::workload::SyntheticGain;

fn main() {
    println!("== Fig 6: full sweep (1000-4000 jobs × 4k-16k cores) ==");
    let out = fig6_sched_time(5);
    println!("{}", out.summary);

    println!("== single-cell latency distribution ==");
    let mut rng = Rng::new(1);
    for (jobs, cores) in [(1000usize, 4096u32), (4000, 16384)] {
        let gains: Vec<SyntheticGain> = (0..jobs)
            .map(|_| SyntheticGain {
                scale: rng.range_f64(0.01, 2.0),
                rate: rng.range_f64(0.02, 0.5),
            })
            .collect();
        let caps: Vec<u32> = (0..jobs).map(|_| rng.range_u64(32, 129) as u32).collect();
        let requests: Vec<JobRequest<'_>> = gains
            .iter()
            .enumerate()
            .map(|(i, g)| JobRequest { id: i as u64, max_cores: caps[i], gain: g })
            .collect();
        let mut policy = SlaqPolicy::new();
        bench(&format!("slaq_allocate_{jobs}x{cores}"), 2, 20, || {
            common::black_box(policy.allocate(&requests, cores));
        });
    }
}
