//! Bench (ablation): SLAQ's greedy allocator vs the fair / FIFO / static
//! baselines at identical scale — quantifies the cost of quality-driven
//! scheduling over quality-agnostic policies.

#[path = "common.rs"]
mod common;

use common::{bench, black_box};
use slaq::sched::{policy_by_name, JobRequest};
use slaq::util::rng::Rng;
use slaq::workload::SyntheticGain;

fn main() {
    let jobs = 2000usize;
    let cores = 8192u32;
    let mut rng = Rng::new(11);
    let gains: Vec<SyntheticGain> = (0..jobs)
        .map(|_| SyntheticGain {
            scale: rng.range_f64(0.01, 2.0),
            rate: rng.range_f64(0.02, 0.5),
        })
        .collect();
    let caps: Vec<u32> = (0..jobs).map(|_| rng.range_u64(32, 129) as u32).collect();
    let requests: Vec<JobRequest<'_>> = gains
        .iter()
        .enumerate()
        .map(|(i, g)| JobRequest { id: i as u64, max_cores: caps[i], prev_cores: 0, gain: g })
        .collect();

    for name in ["slaq", "fair", "fifo", "static"] {
        let mut policy = policy_by_name(name).unwrap();
        bench(&format!("allocate_{name}_{jobs}x{cores}"), 3, 30, || {
            black_box(policy.allocate(&requests, cores));
        });
    }
}
