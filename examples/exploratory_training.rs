//! Exploratory training — the paper's motivating scenario (§1): a
//! practitioner sweeps hyperparameters with many short retraining jobs and
//! wants approximate models *fast*, not perfectly converged ones.
//!
//! Twelve REAL logistic-regression jobs with different learning rates are
//! submitted under SLAQ and under the fair scheduler; we report when each
//! job reached 90% of the loss reduction it would eventually achieve.
//!
//! Run with:  cargo run --release --example exploratory_training

use anyhow::Result;
use slaq::cluster::{ClusterSpec, CostModel};
use slaq::coordinator::{Coordinator, CoordinatorConfig, JobSpec, Trace};
use slaq::mltrain::{AlgoKind, ExecSource, TrainSession};
use slaq::predictor::CurveKind;
use slaq::runtime::{Manifest, Runtime, RuntimeConfig};
use slaq::sched::policy_by_name;
use slaq::util::stats::mean;

const LRS: [f32; 12] = [
    0.01, 0.02, 0.05, 0.1, 0.2, 0.3, 0.5, 0.8, 1.0, 1.5, 2.0, 3.0,
];

fn run(policy: &str, rt: &Runtime, manifest: &Manifest) -> Result<Trace> {
    let cfg = CoordinatorConfig {
        cluster: ClusterSpec { nodes: 1, cores_per_node: 16 },
        epoch_secs: 2.0,
        ..Default::default()
    };
    let mut coord = Coordinator::new(cfg, policy_by_name(policy).unwrap());
    for (i, lr) in LRS.iter().enumerate() {
        // Same data (same seed), different learning rate: a classic sweep.
        let session = TrainSession::new_with_hypers(
            rt,
            manifest,
            "small",
            AlgoKind::LogregGd,
            7,
            Some(&[*lr, 1e-4]),
        )?;
        let spec = JobSpec {
            id: i as u64,
            name: format!("logreg-lr{lr}"),
            kind: CurveKind::Sublinear,
            cost: CostModel::new(0.05, 8.0),
            max_cores: 16,
            arrival: 3.0 * i as f64,
            target_fraction: 0.95,
            max_iterations: 250,
            target_hint: None,
        };
        coord.submit(spec, Box::new(ExecSource::new(session)));
    }
    coord.run_to_completion(4000);
    Ok(coord.into_trace())
}

/// Time (from activation) to reach 90% of the reduction the job finally
/// achieved. Real runs have no a-priori floor, so use the achieved minimum.
fn time_to_90(trace: &Trace) -> Vec<(String, f64)> {
    trace
        .jobs
        .iter()
        .filter_map(|j| {
            let min = j
                .samples
                .iter()
                .map(|s| s.2)
                .fold(f64::INFINITY, f64::min);
            let span = j.initial_loss - min;
            if span <= 0.0 {
                return None;
            }
            let threshold = j.initial_loss - 0.9 * span;
            j.samples
                .iter()
                .find(|s| s.2 <= threshold)
                .map(|s| (j.name.clone(), s.0 - j.activated))
        })
        .collect()
}

fn main() -> Result<()> {
    let rt = Runtime::cpu(RuntimeConfig::default())?;
    let manifest = Manifest::load(std::path::Path::new("artifacts"))?;

    println!("sweeping 12 learning rates under SLAQ and fair scheduling…\n");
    let slaq_trace = run("slaq", &rt, &manifest)?;
    let fair_trace = run("fair", &rt, &manifest)?;

    let ts = time_to_90(&slaq_trace);
    let tf = time_to_90(&fair_trace);

    println!("{:<16} {:>12} {:>12}", "job", "slaq t90(s)", "fair t90(s)");
    for (name, t_slaq) in &ts {
        let t_fair = tf
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, t)| *t)
            .unwrap_or(f64::NAN);
        println!("{name:<16} {t_slaq:>12.1} {t_fair:>12.1}");
    }
    let (ms, mf) = (
        mean(&ts.iter().map(|x| x.1).collect::<Vec<_>>()),
        mean(&tf.iter().map(|x| x.1).collect::<Vec<_>>()),
    );
    println!(
        "\nmean time-to-90%: slaq {ms:.1}s vs fair {mf:.1}s ({:.0}% faster)",
        100.0 * (1.0 - ms / mf)
    );
    Ok(())
}
