//! Cluster experiment — the paper's Figs 3–5 at configurable scale via the
//! library API (the `slaq exp` CLI wraps the same drivers).
//!
//! Run with:  cargo run --release --example cluster_experiment [jobs]

use slaq::cluster::ClusterSpec;
use slaq::exp::{fig3_allocation, fig4_avg_loss, fig5_time_to, run_sim_trace, SimConfig};
use slaq::workload::TraceConfig;

fn main() {
    let jobs: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(80);
    let cfg = SimConfig {
        trace: TraceConfig { jobs, mean_interarrival: 15.0, seed: 20818 },
        cluster: ClusterSpec::paper_testbed(),
        epoch_secs: 3.0,
        duration: 1800.0,
        threads: 0, // all cores: sharded refits + materialized gain tables
    };
    println!(
        "simulating {} jobs on {} cores under slaq + fair…",
        jobs,
        cfg.cluster.capacity()
    );
    let slaq_trace = run_sim_trace(&cfg, "slaq");
    let fair_trace = run_sim_trace(&cfg, "fair");

    for out in [
        fig3_allocation(&slaq_trace),
        fig4_avg_loss(&slaq_trace, &fair_trace),
        fig5_time_to(&slaq_trace, &fair_trace),
    ] {
        println!("{}", out.summary);
    }
}
