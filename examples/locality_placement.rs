//! Locality scenario: rack-aware vs rack-blind placement on a multi-rack
//! cluster, at the churn scale (4000–16000 jobs). Prints the mean rack
//! span, cross-rack cores moved per epoch and the fidelity-style
//! invariant verdict for each population size.
//!
//! Run with:  cargo run --release --example locality_placement

use slaq::exp::locality_placement;

fn main() {
    // 2 zones × 8 racks over the 16384-core (512-node) churn cluster;
    // the same sweep `slaq exp locality` runs.
    let out = locality_placement(&[4000, 8000, 16000], 16384, 2, 8, 32, 12, 0);
    println!("{}", out.summary);
}
