//! Policy tournament: all six schedulers (slaq, slaq-det, fair, oasis,
//! shockwave, learned) across three workload cells — churny arrivals,
//! contention-heavy demand, and heterogeneous quality targets — scored on
//! mean normalized loss, time-to-90/95% loss reduction and quality
//! fairness (Jain index), with per-epoch allocator invariants asserted.
//!
//! Run with:  cargo run --release --example policy_tournament

use slaq::exp::{run_tournament, TournamentConfig};

fn main() {
    // The same grid `slaq exp tournament` runs; panics if any run
    // violates a capacity / per-job cap / work-conservation invariant.
    let report = run_tournament(&TournamentConfig::default());
    report.assert_ok();
    println!("{}", report.output().summary);
}
