//! Scheduler scalability — the paper's Fig 6 (SLAQ allocation decision
//! time for thousands of jobs across thousands of cores) plus the churn
//! scenario: steady-state epochs where only a handful of jobs turn over,
//! comparing the incremental (warm-start) decision path to from-scratch.
//!
//! Run with:  cargo run --release --example scheduler_scalability

use slaq::exp::{churn_scalability, fig6_sched_time};

fn main() {
    let out = fig6_sched_time(3);
    println!("{}", out.summary);

    let churn = churn_scalability(&[1000, 2000, 4000], 16384, 32, 12);
    println!("{}", churn.summary);
}
