//! Scheduler scalability — the paper's Fig 6: SLAQ allocation decision
//! time for thousands of jobs across thousands of cores.
//!
//! Run with:  cargo run --release --example scheduler_scalability

use slaq::exp::fig6_sched_time;

fn main() {
    let out = fig6_sched_time(3);
    println!("{}", out.summary);
}
