//! Scheduler scalability — the paper's Fig 6 (SLAQ allocation decision
//! time for thousands of jobs across thousands of cores) plus the two
//! churn scenarios: the allocator microbenchmark (incremental warm-start
//! vs from-scratch decisions) and the end-to-end coordinator epoch loop
//! (ledger activation, predictor refits, allocation, placement diffs).
//!
//! Run with:  cargo run --release --example scheduler_scalability

use slaq::exp::{churn_epoch_loop, churn_scalability, fig6_sched_time};

fn main() {
    let out = fig6_sched_time(3);
    println!("{}", out.summary);

    let churn = churn_scalability(&[1000, 2000, 4000], 16384, 32, 12);
    println!("{}", churn.summary);

    let epoch = churn_epoch_loop(&[1000, 2000, 4000], 16384, 32, 12);
    println!("{}", epoch.summary);
}
