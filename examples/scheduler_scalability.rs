//! Scheduler scalability — the paper's Fig 6 (SLAQ allocation decision
//! time for thousands of jobs across thousands of cores) plus the two
//! churn scenarios: the allocator microbenchmark (incremental warm-start
//! vs from-scratch decisions) and the end-to-end coordinator epoch loop
//! (ledger activation, sharded predictor refits, gain-table builds,
//! allocation, placement diffs) at 1000–16000 jobs, once on the serial
//! reference path and once on the machine's full parallelism — then the
//! sharded coordinator (per-zone shard allocators + budget broker),
//! flat vs sharded rows side by side up to the 100 000-job cell.
//!
//! Run with:  cargo run --release --example scheduler_scalability

use slaq::exp::{churn_epoch_loop, churn_scalability, fig6_sched_time};

fn main() {
    let out = fig6_sched_time(3);
    println!("{}", out.summary);

    let churn = churn_scalability(&[1000, 2000, 4000], 16384, 32, 12);
    println!("{}", churn.summary);

    let populations = [1000, 2000, 4000, 8000, 16000];
    let serial = churn_epoch_loop(&populations, 16384, 32, 12, 1, 0);
    println!("{}", serial.summary);
    let parallel = churn_epoch_loop(&populations, 16384, 32, 12, 0, 0);
    println!("{}", parallel.summary);

    // The sharded coordinator at scale: 8 zone shards, budgets
    // rebalanced every 8 epochs; the sharded rows' decision p95 is the
    // sub-millisecond target at 100k jobs.
    let sharded = churn_epoch_loop(&[16000, 100_000], 65536, 64, 12, 0, 8);
    println!("{}", sharded.summary);
}
