//! Quickstart — the end-to-end driver: REAL training jobs (AOT-compiled
//! JAX+Pallas steps executed via PJRT) scheduled by SLAQ on a simulated
//! cluster.
//!
//! Run with:  cargo run --release --example quickstart
//! (requires `make artifacts` first)
//!
//! Eight jobs — one per algorithm in the zoo — arrive over the first
//! minute; SLAQ reallocates cores every epoch based on each job's
//! predicted quality gain; per-iteration losses come from actually
//! executing the lowered HLO modules.

use anyhow::Result;
use slaq::cluster::{ClusterSpec, CostModel};
use slaq::coordinator::{Coordinator, CoordinatorConfig, JobSpec};
use slaq::mltrain::{ExecSource, TrainSession, ALL_ALGOS};
use slaq::runtime::{Manifest, Runtime, RuntimeConfig};
use slaq::sched::SlaqPolicy;

fn main() -> Result<()> {
    let rt = Runtime::cpu(RuntimeConfig::default())?;
    let manifest = Manifest::load(std::path::Path::new("artifacts"))?;
    println!("PJRT platform: {}\n", rt.platform_name());

    let cfg = CoordinatorConfig {
        cluster: ClusterSpec { nodes: 2, cores_per_node: 8 },
        epoch_secs: 2.0,
        ..Default::default()
    };
    let mut coord = Coordinator::new(cfg, Box::new(SlaqPolicy::new()));

    for (i, algo) in ALL_ALGOS.iter().enumerate() {
        let session = TrainSession::new(&rt, &manifest, "small", *algo, 100 + i as u64)?;
        let spec = JobSpec {
            id: i as u64,
            name: algo.model_name().to_string(),
            kind: algo.curve_kind(),
            cost: CostModel::new(0.05, 6.0),
            max_cores: 8,
            arrival: 8.0 * i as f64,
            target_fraction: 0.95, // unused: real runs have no known floor
            max_iterations: 300,
            target_hint: None,
        };
        coord.submit(spec, Box::new(ExecSource::new(session)));
    }

    println!("running the SLAQ epoch loop (real PJRT training steps)…");
    coord.run_to_completion(4000);
    let trace = coord.into_trace();

    println!(
        "\n{:<22} {:>6} {:>12} {:>12} {:>12}",
        "job", "iters", "initial", "final", "done@(s)"
    );
    for j in &trace.jobs {
        let final_loss = j.samples.last().map(|s| s.2).unwrap_or(f64::NAN);
        println!(
            "{:<22} {:>6} {:>12.5} {:>12.5} {:>12.1}",
            j.name,
            j.samples.len() - 1,
            j.initial_loss,
            final_loss,
            j.completion.unwrap_or(f64::NAN),
        );
    }
    println!(
        "\n{} epochs, mean scheduling decision {:.3} ms",
        trace.epochs.len(),
        trace.mean_sched_millis()
    );
    Ok(())
}
