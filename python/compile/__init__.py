"""Build-time compile path: JAX models (L2) + Pallas kernels (L1).

Nothing in this package is imported at runtime; `aot.py` lowers every model
to HLO text under `artifacts/`, and the Rust coordinator loads those.
"""
