"""K-Means assignment + accumulation kernel (Pallas, Layer 1).

One fused pass over the points computes, per row tile:

    dists    = ||x_i - c_j||^2          (via the expanded form, MXU matmul)
    assign_i = argmin_j dists
    sums    += onehot(assign)^T @ X_blk
    counts  += sum(onehot(assign))
    loss    += sum_i min_j dists

The (k, d) center matrix stays VMEM-resident across the whole grid; only the
point tiles stream. The caller turns (sums, counts) into the Lloyd update
`centers' = sums / counts` (keeping old centers for empty clusters).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kmeans_kernel(x_ref, c_ref, sums_ref, counts_ref, loss_ref):
    step = pl.program_id(0)
    x = x_ref[...]  # (bm, d)
    c = c_ref[...]  # (k, d)
    k = c.shape[0]

    x_sq = jnp.sum(x * x, axis=1, keepdims=True)  # (bm, 1)
    c_sq = jnp.sum(c * c, axis=1)[None, :]  # (1, k)
    dists = x_sq - 2.0 * (x @ c.T) + c_sq  # (bm, k)
    assign = jnp.argmin(dists, axis=1)  # (bm,)
    onehot = jax.nn.one_hot(assign, k, dtype=x.dtype)  # (bm, k)
    min_dist = jnp.min(dists, axis=1)

    sums_contrib = onehot.T @ x  # (k, d)
    counts_contrib = jnp.sum(onehot, axis=0)  # (k,)
    # Clamp: the expanded-form distance can go slightly negative in f32.
    loss_contrib = jnp.sum(jnp.maximum(min_dist, 0.0))

    @pl.when(step == 0)
    def _init():
        sums_ref[...] = sums_contrib
        counts_ref[...] = counts_contrib
        loss_ref[...] = jnp.full((1,), loss_contrib, dtype=loss_ref.dtype)

    @pl.when(step != 0)
    def _accumulate():
        sums_ref[...] += sums_contrib
        counts_ref[...] += counts_contrib
        loss_ref[...] += loss_contrib


@functools.partial(jax.jit, static_argnames=("block_rows",))
def kmeans_assign(x, centers, *, block_rows=512):
    """Assignment step of Lloyd's algorithm, fused with accumulation.

    Args:
      x: (n, d) points.
      centers: (k, d) current centers.
      block_rows: row-tile size.

    Returns:
      (sums, counts, loss): (k, d) per-cluster coordinate sums, (k,) member
      counts, and (1,) total within-cluster squared distance.
    """
    n, d = x.shape
    k, dc = centers.shape
    if dc != d:
        raise ValueError(f"centers dim {dc} != points dim {d}")
    bm = min(block_rows, n)
    if n % bm != 0:
        raise ValueError(f"n={n} must be divisible by block_rows={bm}")
    grid = (n // bm,)

    sums, counts, loss = pl.pallas_call(
        _kmeans_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((k, d), lambda i: (0, 0)),
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, d), x.dtype),
            jax.ShapeDtypeStruct((k,), x.dtype),
            jax.ShapeDtypeStruct((1,), x.dtype),
        ],
        interpret=True,
    )(x, centers)
    return sums, counts, loss
