"""Layer-1 Pallas kernels (interpret=True for CPU-PJRT execution)."""

from .glm_grad import glm_grad
from .kmeans import kmeans_assign

__all__ = ["glm_grad", "kmeans_assign"]
