"""Fused GLM gradient kernel (Pallas, Layer 1).

Computes, in a single pass over the data, the mean gradient and mean loss of
a generalized linear model:

    z = X @ w
    linear:    r = z - y            loss = 0.5 (z - y)^2          (linreg)
    logistic:  r = sigmoid(z) - y   loss = BCE(sigmoid(z), y)     (logreg)
    hinge:     r = -y * 1[y z < 1]  loss = max(0, 1 - y z)        (SVM)

    grad = X^T r / n,   loss = sum(loss_i) / n

This is the compute hot-spot of every class-I (first-order) workload in the
paper's algorithm zoo. The TPU mapping (DESIGN.md §3): X is tiled into
(block_rows, d) row blocks streamed HBM→VMEM over a 1-D grid; `z = X_blk @ w`
runs on the MXU; the activation runs on the VPU; `X_blk^T r` accumulates into
a VMEM-resident (d,) accumulator. VMEM footprint per step is
`block_rows*d + 2*d + 2*block_rows` floats (~1.1 MB at 4096x64 f32).

Lowered with `interpret=True`: the CPU PJRT client cannot execute Mosaic
custom-calls, and interpret mode lowers to plain HLO with identical numerics.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ACTIVATIONS = ("linear", "logistic", "hinge")


def _residual_and_loss(z, y, activation):
    """Per-example residual (dL/dz) and loss for the given activation."""
    if activation == "linear":
        r = z - y
        loss = 0.5 * (z - y) ** 2
    elif activation == "logistic":
        p = jax.nn.sigmoid(z)
        r = p - y
        # Numerically stable BCE in terms of logits.
        loss = jnp.maximum(z, 0.0) - z * y + jnp.log1p(jnp.exp(-jnp.abs(z)))
    elif activation == "hinge":
        margin = y * z
        active = (margin < 1.0).astype(z.dtype)
        r = -y * active
        loss = jnp.maximum(0.0, 1.0 - margin)
    else:  # pragma: no cover - guarded by the public wrapper
        raise ValueError(f"unknown activation {activation!r}")
    return r, loss


def _glm_grad_kernel(x_ref, w_ref, y_ref, grad_ref, loss_ref, *, activation, n_total):
    step = pl.program_id(0)
    x = x_ref[...]  # (bm, d)
    w = w_ref[...]  # (d,)
    y = y_ref[...]  # (bm,)

    z = x @ w
    r, loss = _residual_and_loss(z, y, activation)
    grad_contrib = x.T @ r / n_total
    loss_contrib = jnp.sum(loss) / n_total

    @pl.when(step == 0)
    def _init():
        grad_ref[...] = grad_contrib
        loss_ref[...] = jnp.full((1,), loss_contrib, dtype=loss_ref.dtype)

    @pl.when(step != 0)
    def _accumulate():
        grad_ref[...] += grad_contrib
        loss_ref[...] += loss_contrib


@functools.partial(jax.jit, static_argnames=("activation", "block_rows"))
def glm_grad(x, w, y, *, activation="logistic", block_rows=512):
    """Mean GLM gradient and loss in one fused pass.

    Args:
      x: (n, d) design matrix.
      w: (d,) weights.
      y: (n,) targets ({0,1} for logistic, {-1,+1} for hinge, reals for
        linear).
      activation: one of "linear" | "logistic" | "hinge".
      block_rows: row-tile size (the HBM->VMEM streaming granularity).

    Returns:
      (grad, loss): (d,) mean gradient and scalar-shaped (1,) mean loss.
    """
    if activation not in ACTIVATIONS:
        raise ValueError(f"unknown activation {activation!r}")
    n, d = x.shape
    if w.shape != (d,):
        raise ValueError(f"w shape {w.shape} incompatible with x {x.shape}")
    if y.shape != (n,):
        raise ValueError(f"y shape {y.shape} incompatible with x {x.shape}")
    bm = min(block_rows, n)
    if n % bm != 0:
        raise ValueError(f"n={n} must be divisible by block_rows={bm}")
    grid = (n // bm,)

    kernel = functools.partial(
        _glm_grad_kernel, activation=activation, n_total=float(n)
    )
    grad, loss = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((bm,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d,), x.dtype),
            jax.ShapeDtypeStruct((1,), x.dtype),
        ],
        interpret=True,
    )(x, w, y)
    return grad, loss
