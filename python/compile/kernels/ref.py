"""Pure-jnp oracles for the Pallas kernels (the correctness ground truth).

These are deliberately written in the most obvious vectorized style, with no
tiling and no fusion, so a mismatch can only come from the kernels.
"""

import jax
import jax.numpy as jnp


def glm_grad_ref(x, w, y, *, activation="logistic"):
    """Reference for `kernels.glm_grad`: mean gradient + (1,) mean loss."""
    n = x.shape[0]
    z = x @ w
    if activation == "linear":
        r = z - y
        loss = 0.5 * (z - y) ** 2
    elif activation == "logistic":
        p = jax.nn.sigmoid(z)
        r = p - y
        loss = jnp.maximum(z, 0.0) - z * y + jnp.log1p(jnp.exp(-jnp.abs(z)))
    elif activation == "hinge":
        margin = y * z
        active = (margin < 1.0).astype(z.dtype)
        r = -y * active
        loss = jnp.maximum(0.0, 1.0 - margin)
    else:
        raise ValueError(f"unknown activation {activation!r}")
    grad = x.T @ r / n
    return grad, jnp.sum(loss, keepdims=True) / n


def kmeans_assign_ref(x, centers):
    """Reference for `kernels.kmeans_assign`: (sums, counts, (1,) loss)."""
    dists = jnp.sum((x[:, None, :] - centers[None, :, :]) ** 2, axis=2)
    assign = jnp.argmin(dists, axis=1)
    k = centers.shape[0]
    onehot = jax.nn.one_hot(assign, k, dtype=x.dtype)
    sums = onehot.T @ x
    counts = jnp.sum(onehot, axis=0)
    loss = jnp.sum(jnp.min(dists, axis=1), keepdims=True)
    return sums, counts, loss
