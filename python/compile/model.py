"""Layer-2 JAX train steps: the paper's algorithm zoo.

Each function performs ONE BSP training iteration (one full pass over the
batch) and returns `(new_params..., loss)`. Shapes are static so every
function lowers to a single HLO module; hyperparameters (learning rate,
regularization) are traced scalars so one artifact serves many job configs.

Convergence classes (paper §2):

  class I  (sublinear, first-order): linreg_gd, logreg_gd, svm_gd,
           svm_poly_gd, mlp_gd
  class II (linear / superlinear):   kmeans_step, gmm_em_step (EM family,
           substitutes the paper's LDA), newton_logreg_step (substitutes
           the paper's L-BFGS / GBT entries — same convergence class)

Substitutions are documented in DESIGN.md §2.
"""

import jax
import jax.numpy as jnp

from .kernels import glm_grad, kmeans_assign

# ---------------------------------------------------------------------------
# Class I — first-order gradient methods (use the fused Pallas GLM kernel)
# ---------------------------------------------------------------------------


def linreg_gd(w, x, y, lr, reg):
    """Linear regression, one GD step on 0.5*MSE + 0.5*reg*|w|^2."""
    grad, loss = glm_grad(x, w, y, activation="linear")
    grad = grad + reg * w
    loss = loss + 0.5 * reg * jnp.sum(w * w)
    return w - lr * grad, loss


def logreg_gd(w, x, y, lr, reg):
    """Logistic regression (y in {0,1}), one GD step on BCE + L2."""
    grad, loss = glm_grad(x, w, y, activation="logistic")
    grad = grad + reg * w
    loss = loss + 0.5 * reg * jnp.sum(w * w)
    return w - lr * grad, loss


def svm_gd(w, x, y, lr, reg):
    """Linear SVM (y in {-1,+1}), one subgradient step on hinge + L2."""
    grad, loss = glm_grad(x, w, y, activation="hinge")
    grad = grad + reg * w
    loss = loss + 0.5 * reg * jnp.sum(w * w)
    return w - lr * grad, loss


def poly_expand(x):
    """Degree-2 feature map: [x, x^2, 1] (the SVM polynomial-kernel
    stand-in, intercept included).

    The paper extends Spark MLlib with SVM polynomial kernels; an explicit
    low-degree feature map exercises the same compute pattern (a wider GLM)
    while keeping shapes static for AOT.
    """
    ones = jnp.ones((x.shape[0], 1), dtype=x.dtype)
    return jnp.concatenate([x, x * x, ones], axis=1)


def svm_poly_gd(w, x, y, lr, reg):
    """Polynomial-kernel SVM via explicit degree-2 feature expansion.

    `w` has dimension `2 d + 1`; the expansion happens inside the step so
    the artifact consumes the raw (n, d) batch.
    """
    phi = poly_expand(x)
    grad, loss = glm_grad(phi, w, y, activation="hinge")
    grad = grad + reg * w
    loss = loss + 0.5 * reg * jnp.sum(w * w)
    return w - lr * grad, loss


def mlp_gd(w1, b1, w2, b2, x, y, lr, reg):
    """One-hidden-layer MLP classifier (MLPC stand-in), one GD step on BCE.

    tanh hidden layer, sigmoid output; autodiff through the whole graph.
    """

    def bce(params, x, y):
        w1, b1, w2, b2 = params
        h = jnp.tanh(x @ w1 + b1)
        z = h @ w2 + b2
        loss = jnp.maximum(z, 0.0) - z * y + jnp.log1p(jnp.exp(-jnp.abs(z)))
        l2 = 0.5 * reg * (jnp.sum(w1 * w1) + jnp.sum(w2 * w2))
        return jnp.mean(loss) + l2

    params = (w1, b1, w2, b2)
    loss, grads = jax.value_and_grad(bce)(params, x, y)
    new = tuple(p - lr * g for p, g in zip(params, grads))
    return (*new, jnp.reshape(loss, (1,)))


# ---------------------------------------------------------------------------
# Class II — linear/superlinear methods
# ---------------------------------------------------------------------------


def kmeans_step(centers, x):
    """One Lloyd iteration (uses the fused Pallas assignment kernel).

    Empty clusters keep their previous center. Loss is the mean
    within-cluster squared distance.
    """
    sums, counts, loss = kmeans_assign(x, centers)
    n = x.shape[0]
    safe = jnp.maximum(counts, 1.0)[:, None]
    new_centers = jnp.where(counts[:, None] > 0.0, sums / safe, centers)
    return new_centers, loss / n


def gmm_em_step(means, log_weights, x):
    """One EM iteration of a spherical (unit-variance) Gaussian mixture.

    Substitutes the paper's LDA workload: LDA's variational EM and GMM EM
    are the same algorithmic family with the same (linear-rate) convergence
    behaviour. Loss is the mean negative log-likelihood.
    """
    # E-step: responsibilities (n, k).
    d = x.shape[1]
    sq = jnp.sum((x[:, None, :] - means[None, :, :]) ** 2, axis=2)
    log_p = log_weights[None, :] - 0.5 * sq - 0.5 * d * jnp.log(2.0 * jnp.pi)
    log_norm = jax.scipy.special.logsumexp(log_p, axis=1, keepdims=True)
    resp = jnp.exp(log_p - log_norm)
    # M-step.
    nk = jnp.sum(resp, axis=0)  # (k,)
    safe = jnp.maximum(nk, 1e-6)
    new_means = (resp.T @ x) / safe[:, None]
    new_log_weights = jnp.log(safe / x.shape[0])
    loss = -jnp.mean(log_norm)
    return new_means, new_log_weights, jnp.reshape(loss, (1,))


def _cg_solve(a_mat, b, iters):
    """Conjugate gradients for SPD `a_mat x = b`, unrolled `iters` steps.

    Pure jnp dataflow (no LAPACK custom calls): xla_extension 0.5.1 — the
    XLA behind the Rust runtime — rejects the typed-FFI custom-call that
    `jax.scipy.linalg.solve` lowers to. CG on an SPD d×d system converges
    in at most d steps in exact arithmetic.
    """
    x = jnp.zeros_like(b)
    r = b
    p = r
    rs = jnp.dot(r, r)
    for _ in range(iters):
        ap = a_mat @ p
        alpha = rs / jnp.maximum(jnp.dot(p, ap), 1e-30)
        x = x + alpha * p
        r = r - alpha * ap
        rs_new = jnp.dot(r, r)
        beta = rs_new / jnp.maximum(rs, 1e-30)
        p = r + beta * p
        rs = rs_new
    return x


def newton_logreg_step(w, x, y, reg):
    """One Newton–Raphson step for L2-regularized logistic regression.

    Class II (quadratic convergence): stands in for the paper's L-BFGS and
    GBT workloads, which share the linear/superlinear convergence category.
    The gradient reuses the fused Pallas kernel; the d×d Newton system
    `(X^T D X / n + reg I) δ = grad` is solved with unrolled CG.
    """
    n = x.shape[0]
    d = x.shape[1]
    grad, loss = glm_grad(x, w, y, activation="logistic")
    grad = grad + reg * w
    loss = loss + 0.5 * reg * jnp.sum(w * w)
    z = x @ w
    p = jax.nn.sigmoid(z)
    dvec = p * (1.0 - p) / n  # (n,)
    hess = x.T @ (dvec[:, None] * x) + reg * jnp.eye(d, dtype=x.dtype)
    step = _cg_solve(hess, grad, iters=d)
    return w - step, loss


# ---------------------------------------------------------------------------
# Registry used by aot.py and the tests
# ---------------------------------------------------------------------------


def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def model_registry(n=2048, d=32, k=8, h=16):
    """All lowering targets: name -> (fn, example_args, param_count).

    `param_count` is the number of leading arguments that are trainable
    state (fed back between iterations); the remainder are data + hypers.
    Outputs are always `(*new_params, loss)`.
    """
    scalar = _f32()
    return {
        "linreg_gd": (linreg_gd, [_f32(d), _f32(n, d), _f32(n), scalar, scalar], 1),
        "logreg_gd": (logreg_gd, [_f32(d), _f32(n, d), _f32(n), scalar, scalar], 1),
        "svm_gd": (svm_gd, [_f32(d), _f32(n, d), _f32(n), scalar, scalar], 1),
        "svm_poly_gd": (
            svm_poly_gd,
            [_f32(2 * d + 1), _f32(n, d), _f32(n), scalar, scalar],
            1,
        ),
        "mlp_gd": (
            mlp_gd,
            [_f32(d, h), _f32(h), _f32(h), scalar, _f32(n, d), _f32(n), scalar, scalar],
            4,
        ),
        "kmeans_step": (kmeans_step, [_f32(k, d), _f32(n, d)], 1),
        "gmm_em_step": (gmm_em_step, [_f32(k, d), _f32(k), _f32(n, d)], 2),
        "newton_logreg_step": (
            newton_logreg_step,
            [_f32(d), _f32(n, d), _f32(n), scalar],
            1,
        ),
    }
