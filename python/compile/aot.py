"""AOT lowering: JAX train steps -> HLO text artifacts for the Rust runtime.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the published `xla` crate) rejects; the text parser reassigns
ids and round-trips cleanly.

Usage:  python -m compile.aot --out-dir ../artifacts [--small]

Writes one `<name>_n<N>_d<D>.hlo.txt` per registry entry plus a
`manifest.json` describing every artifact's argument/output layout, which
the Rust `mltrain` engine reads to drive training generically.
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from .model import model_registry


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_all(out_dir: str, n: int, d: int, k: int, h: int, variant: str) -> dict:
    """Lower every registry model; returns the manifest dict."""
    os.makedirs(out_dir, exist_ok=True)
    registry = model_registry(n=n, d=d, k=k, h=h)
    manifest = {
        "variant": variant,
        "n": n,
        "d": d,
        "k": k,
        "h": h,
        "models": {},
    }
    for name, (fn, example_args, param_count) in registry.items():
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        artifact = f"{name}_{variant}"
        path = os.path.join(out_dir, f"{artifact}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["models"][name] = {
            "artifact": artifact,
            "param_count": param_count,
            "args": [
                {"shape": list(a.shape), "dtype": str(a.dtype)} for a in example_args
            ],
            "num_outputs": param_count + 1,  # new params + loss
        }
        print(f"  {artifact}: {len(text)} chars, {len(example_args)} args")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--n", type=int, default=2048, help="batch rows")
    ap.add_argument("--d", type=int, default=32, help="feature dim")
    ap.add_argument("--k", type=int, default=8, help="clusters/components")
    ap.add_argument("--h", type=int, default=16, help="MLP hidden width")
    ap.add_argument(
        "--small",
        action="store_true",
        help="also emit a small (n=256) variant used by fast tests",
    )
    args = ap.parse_args()

    manifests = [lower_all(args.out_dir, args.n, args.d, args.k, args.h, "base")]
    if args.small:
        manifests.append(lower_all(args.out_dir, 256, args.d, args.k, args.h, "small"))

    merged = {"variants": {m["variant"]: m for m in manifests}}
    manifest_path = os.path.join(args.out_dir, "manifest.json")
    with open(manifest_path, "w") as f:
        json.dump(merged, f, indent=2, sort_keys=True)
    print(f"wrote {manifest_path}")


if __name__ == "__main__":
    main()
