"""L2 correctness: every train step decreases its loss on a learnable
synthetic problem, preserves shapes, and (where applicable) matches a
from-scratch jnp reference.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model as M

N, D, K, H = 256, 8, 4, 8


def _separable(seed=0, n=N, d=D, labels="01"):
    """Linearly separable-ish classification data."""
    rng = np.random.default_rng(seed)
    w_true = rng.normal(size=d)
    x = rng.normal(size=(n, d))
    logits = x @ w_true + 0.5 * rng.normal(size=n)
    if labels == "01":
        y = (logits > 0).astype(np.float32)
    else:
        y = np.where(logits > 0, 1.0, -1.0).astype(np.float32)
    return jnp.asarray(x, jnp.float32), jnp.asarray(y)


def _blobs(seed=0, n=N, d=D, k=K):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(k, d)) * 4.0
    assign = rng.integers(0, k, size=n)
    x = centers[assign] + rng.normal(size=(n, d))
    return jnp.asarray(x, jnp.float32)


def _run(step, params, args, iters):
    losses = []
    for _ in range(iters):
        out = step(*params, *args)
        params = out[:-1]
        losses.append(float(out[-1][0]) if out[-1].shape else float(out[-1]))
    return params, losses


lr = jnp.float32(0.5)
reg = jnp.float32(1e-4)


class TestClassOne:
    def test_linreg_converges(self):
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.normal(size=(N, D)), jnp.float32)
        w_true = jnp.asarray(rng.normal(size=D), jnp.float32)
        y = x @ w_true
        w = jnp.zeros(D, jnp.float32)
        (_w,), losses = _run(M.linreg_gd, (w,), (x, y, jnp.float32(0.2), reg), 60)
        assert losses[-1] < 0.05 * losses[0]
        assert all(b <= a + 1e-6 for a, b in zip(losses, losses[1:]))

    def test_logreg_converges(self):
        x, y = _separable(labels="01")
        w = jnp.zeros(D, jnp.float32)
        _, losses = _run(M.logreg_gd, (w,), (x, y, lr, reg), 80)
        assert losses[-1] < 0.5 * losses[0]

    def test_svm_converges(self):
        x, y = _separable(labels="pm1")
        w = jnp.zeros(D, jnp.float32)
        _, losses = _run(M.svm_gd, (w,), (x, y, jnp.float32(0.1), reg), 80)
        assert losses[-1] < 0.5 * losses[0]

    def test_svm_poly_converges_on_quadratic_boundary(self):
        rng = np.random.default_rng(5)
        x = rng.normal(size=(N, D)).astype(np.float32)
        # Label depends on squared features: linear SVM can't separate,
        # the degree-2 map can.
        y = np.where((x**2).sum(axis=1) > D, 1.0, -1.0).astype(np.float32)
        w = jnp.zeros(2 * D + 1, jnp.float32)
        _, losses = _run(
            M.svm_poly_gd, (w,), (jnp.asarray(x), jnp.asarray(y), jnp.float32(0.05), reg), 120
        )
        assert losses[-1] < 0.6 * losses[0]

    def test_logreg_step_matches_manual_grad(self):
        x, y = _separable(labels="01", seed=7)
        w = jnp.asarray(np.random.default_rng(8).normal(size=D) * 0.1, jnp.float32)
        w2, _ = M.logreg_gd(w, x, y, lr, reg)

        def bce(w):
            z = x @ w
            return (
                jnp.mean(jnp.maximum(z, 0.0) - z * y + jnp.log1p(jnp.exp(-jnp.abs(z))))
                + 0.5 * reg * jnp.sum(w * w)
            )

        w2_ref = w - lr * jax.grad(bce)(w)
        assert_allclose(np.asarray(w2), np.asarray(w2_ref), rtol=1e-4, atol=1e-6)

    def test_mlp_converges_and_preserves_shapes(self):
        x, y = _separable(labels="01", seed=9)
        rng = np.random.default_rng(10)
        params = (
            jnp.asarray(rng.normal(size=(D, H)) * 0.3, jnp.float32),
            jnp.zeros(H, jnp.float32),
            jnp.asarray(rng.normal(size=H) * 0.3, jnp.float32),
            jnp.float32(0.0),
        )
        out = M.mlp_gd(*params, x, y, lr, reg)
        assert out[0].shape == (D, H)
        assert out[1].shape == (H,)
        assert out[2].shape == (H,)
        assert out[3].shape == ()
        assert out[4].shape == (1,)
        _, losses = _run(M.mlp_gd, params, (x, y, lr, reg), 120)
        assert losses[-1] < 0.7 * losses[0]


class TestClassTwo:
    def test_kmeans_monotone_decrease(self):
        x = _blobs(seed=2)
        rng = np.random.default_rng(3)
        centers = jnp.asarray(x[rng.choice(N, K, replace=False)])
        _, losses = _run(M.kmeans_step, (centers,), (x,), 20)
        # Lloyd's algorithm is monotonically non-increasing.
        assert all(b <= a + 1e-4 for a, b in zip(losses, losses[1:]))
        assert losses[-1] < losses[0]

    def test_kmeans_keeps_empty_cluster_centers(self):
        x = _blobs(seed=4)
        far = jnp.full((1, D), 1e6, jnp.float32)  # never owns a point
        centers = jnp.concatenate([jnp.asarray(x[:K - 1]), far])
        new_centers, _ = M.kmeans_step(centers, x)
        assert_allclose(np.asarray(new_centers[-1]), np.asarray(far[0]))

    def test_gmm_em_loglik_improves(self):
        x = _blobs(seed=6)
        rng = np.random.default_rng(7)
        means = jnp.asarray(rng.normal(size=(K, D)), jnp.float32)
        log_w = jnp.full(K, -np.log(K), jnp.float32)
        (_, _), losses = _run(M.gmm_em_step, (means, log_w), (x,), 25)
        # EM is monotone in log-likelihood (loss = negative mean ll).
        assert all(b <= a + 1e-4 for a, b in zip(losses, losses[1:]))
        assert losses[-1] < losses[0]

    def test_gmm_weights_stay_normalized(self):
        x = _blobs(seed=8)
        means = jnp.asarray(np.random.default_rng(9).normal(size=(K, D)), jnp.float32)
        log_w = jnp.full(K, -np.log(K), jnp.float32)
        for _ in range(5):
            means, log_w, _ = M.gmm_em_step(means, log_w, x)
        assert abs(float(jnp.sum(jnp.exp(log_w))) - 1.0) < 1e-4

    def test_newton_converges_quadratically(self):
        x, y = _separable(labels="01", seed=11)
        w = jnp.zeros(D, jnp.float32)
        _, losses = _run(M.newton_logreg_step, (w,), (x, y, jnp.float32(1e-3)), 8)
        # Newton should essentially converge within a handful of steps.
        assert losses[-1] < 0.6 * losses[0]
        tail_delta = abs(losses[-1] - losses[-2]) / max(losses[0], 1e-9)
        assert tail_delta < 1e-4

    def test_newton_beats_gd_per_iteration(self):
        x, y = _separable(labels="01", seed=12)
        w0 = jnp.zeros(D, jnp.float32)
        _, newton_losses = _run(M.newton_logreg_step, (w0,), (x, y, jnp.float32(1e-3)), 5)
        _, gd_losses = _run(M.logreg_gd, (w0,), (x, y, lr, jnp.float32(1e-3)), 5)
        assert newton_losses[-1] < gd_losses[-1]


class TestRegistry:
    def test_registry_entries_lower_and_shapes_match(self):
        reg = M.model_registry(n=64, d=4, k=3, h=4)
        assert len(reg) == 8
        for name, (fn, args, param_count) in reg.items():
            out_avals = jax.eval_shape(fn, *args)
            assert len(out_avals) == param_count + 1, name
            # New params must have the same shapes as the old ones.
            for i in range(param_count):
                assert out_avals[i].shape == args[i].shape, f"{name} param {i}"
            # Loss is () or (1,).
            assert out_avals[-1].shape in [(), (1,)], name
