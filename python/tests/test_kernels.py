"""L1 correctness: Pallas kernels vs pure-jnp oracles.

This is the core correctness signal for the compiled compute path: the same
kernels, lowered to HLO, are what the Rust runtime executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import glm_grad, kmeans_assign
from compile.kernels.ref import glm_grad_ref, kmeans_assign_ref

ACTIVATIONS = ["linear", "logistic", "hinge"]


def _data(n, d, seed=0, labels="pm1"):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    w = rng.normal(size=(d,)).astype(np.float32) * 0.1
    if labels == "pm1":
        y = rng.choice([-1.0, 1.0], size=n).astype(np.float32)
    elif labels == "01":
        y = rng.choice([0.0, 1.0], size=n).astype(np.float32)
    else:
        y = rng.normal(size=n).astype(np.float32)
    return jnp.asarray(x), jnp.asarray(w), jnp.asarray(y)


class TestGlmGrad:
    @pytest.mark.parametrize("activation", ACTIVATIONS)
    def test_matches_ref_single_block(self, activation):
        x, w, y = _data(128, 8, labels="01" if activation == "logistic" else "pm1")
        grad, loss = glm_grad(x, w, y, activation=activation, block_rows=128)
        g_ref, l_ref = glm_grad_ref(x, w, y, activation=activation)
        assert_allclose(np.asarray(grad), np.asarray(g_ref), rtol=1e-5, atol=1e-6)
        assert_allclose(np.asarray(loss), np.asarray(l_ref), rtol=1e-5, atol=1e-6)

    @pytest.mark.parametrize("activation", ACTIVATIONS)
    def test_matches_ref_multi_block(self, activation):
        """Tiled accumulation across the grid must equal one big pass."""
        x, w, y = _data(512, 16, seed=1, labels="01" if activation == "logistic" else "pm1")
        grad, loss = glm_grad(x, w, y, activation=activation, block_rows=64)
        g_ref, l_ref = glm_grad_ref(x, w, y, activation=activation)
        assert_allclose(np.asarray(grad), np.asarray(g_ref), rtol=1e-5, atol=1e-6)
        assert_allclose(np.asarray(loss), np.asarray(l_ref), rtol=1e-5, atol=1e-6)

    def test_loss_shape_is_one(self):
        x, w, y = _data(64, 4)
        _, loss = glm_grad(x, w, y, activation="hinge", block_rows=64)
        assert loss.shape == (1,)

    def test_gradient_is_autodiff_gradient(self):
        """The fused logistic gradient equals jax.grad of the BCE loss."""
        x, w, y = _data(256, 8, seed=3, labels="01")

        def bce(w):
            z = x @ w
            return jnp.mean(
                jnp.maximum(z, 0.0) - z * y + jnp.log1p(jnp.exp(-jnp.abs(z)))
            )

        grad, _ = glm_grad(x, w, y, activation="logistic", block_rows=128)
        assert_allclose(np.asarray(grad), np.asarray(jax.grad(bce)(w)), rtol=1e-4, atol=1e-6)

    def test_rejects_bad_shapes(self):
        x, w, y = _data(64, 4)
        with pytest.raises(ValueError):
            glm_grad(x, w[:-1], y, activation="linear")
        with pytest.raises(ValueError):
            glm_grad(x, w, y[:-1], activation="linear")
        with pytest.raises(ValueError):
            glm_grad(x, w, y, activation="nope")
        with pytest.raises(ValueError):
            glm_grad(x, w, y, activation="linear", block_rows=48)  # 64 % 48 != 0

    @settings(max_examples=25, deadline=None)
    @given(
        n_blocks=st.integers(1, 4),
        bm=st.sampled_from([32, 64, 128]),
        d=st.integers(2, 24),
        activation=st.sampled_from(ACTIVATIONS),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shape_sweep(self, n_blocks, bm, d, activation, seed):
        n = n_blocks * bm
        labels = "01" if activation == "logistic" else "pm1"
        x, w, y = _data(n, d, seed=seed, labels=labels)
        grad, loss = glm_grad(x, w, y, activation=activation, block_rows=bm)
        g_ref, l_ref = glm_grad_ref(x, w, y, activation=activation)
        assert_allclose(np.asarray(grad), np.asarray(g_ref), rtol=2e-4, atol=1e-5)
        assert_allclose(np.asarray(loss), np.asarray(l_ref), rtol=2e-4, atol=1e-5)


class TestKmeansAssign:
    def _points(self, n, d, k, seed=0):
        rng = np.random.default_rng(seed)
        x = rng.normal(size=(n, d)).astype(np.float32)
        c = rng.normal(size=(k, d)).astype(np.float32)
        return jnp.asarray(x), jnp.asarray(c)

    def test_matches_ref_single_block(self):
        x, c = self._points(128, 8, 5)
        out = kmeans_assign(x, c, block_rows=128)
        ref = kmeans_assign_ref(x, c)
        for got, want in zip(out, ref):
            assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)

    def test_matches_ref_multi_block(self):
        x, c = self._points(512, 12, 7, seed=2)
        out = kmeans_assign(x, c, block_rows=64)
        ref = kmeans_assign_ref(x, c)
        for got, want in zip(out, ref):
            assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)

    def test_counts_sum_to_n(self):
        x, c = self._points(256, 6, 4, seed=3)
        _, counts, _ = kmeans_assign(x, c, block_rows=64)
        assert float(jnp.sum(counts)) == 256.0

    def test_rejects_dim_mismatch(self):
        x, c = self._points(64, 4, 3)
        with pytest.raises(ValueError):
            kmeans_assign(x, c[:, :-1])

    @settings(max_examples=20, deadline=None)
    @given(
        n_blocks=st.integers(1, 3),
        bm=st.sampled_from([32, 64]),
        d=st.integers(2, 16),
        k=st.integers(2, 9),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shape_sweep(self, n_blocks, bm, d, k, seed):
        n = n_blocks * bm
        x, c = self._points(n, d, k, seed=seed)
        out = kmeans_assign(x, c, block_rows=bm)
        ref = kmeans_assign_ref(x, c)
        for got, want in zip(out, ref):
            assert_allclose(np.asarray(got), np.asarray(want), rtol=5e-4, atol=5e-4)
