"""AOT path: lowering produces valid HLO text + a consistent manifest."""

import json

import jax
import pytest

from compile import aot
from compile.model import model_registry


@pytest.fixture(scope="module")
def tiny_manifest(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = aot.lower_all(str(out), n=64, d=4, k=3, h=4, variant="tiny")
    return out, manifest


class TestLowering:
    def test_every_model_lowered(self, tiny_manifest):
        out, manifest = tiny_manifest
        assert set(manifest["models"]) == set(model_registry(64, 4, 3, 4))
        for name, spec in manifest["models"].items():
            path = out / f"{spec['artifact']}.hlo.txt"
            assert path.exists(), name

    def test_hlo_text_is_hlo(self, tiny_manifest):
        out, manifest = tiny_manifest
        for spec in manifest["models"].values():
            text = (out / f"{spec['artifact']}.hlo.txt").read_text()
            # HLO text modules start with "HloModule" and declare ENTRY.
            assert text.startswith("HloModule"), spec["artifact"]
            assert "ENTRY" in text
            # Typed-FFI custom-calls are rejected by xla_extension 0.5.1
            # (the Rust runtime's XLA); the lowering must avoid them.
            assert "api_version=API_VERSION_TYPED_FFI" not in text, spec["artifact"]

    def test_manifest_arg_counts_match_registry(self, tiny_manifest):
        _, manifest = tiny_manifest
        reg = model_registry(64, 4, 3, 4)
        for name, spec in manifest["models"].items():
            fn, example_args, param_count = reg[name]
            assert len(spec["args"]) == len(example_args)
            assert spec["param_count"] == param_count
            assert spec["num_outputs"] == param_count + 1
            for got, want in zip(spec["args"], example_args):
                assert tuple(got["shape"]) == want.shape

    def test_parameter_count_in_hlo_matches(self, tiny_manifest):
        out, manifest = tiny_manifest
        for spec in manifest["models"].values():
            text = (out / f"{spec['artifact']}.hlo.txt").read_text()
            # Count entry parameters: "parameter(i)" instructions.
            n_params = text.count("parameter(")
            assert n_params >= len(spec["args"]), spec["artifact"]

    def test_to_hlo_text_roundtrips_simple_fn(self):
        lowered = jax.jit(lambda x: (x * 2.0,)).lower(
            jax.ShapeDtypeStruct((4,), "float32")
        )
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule")

    def test_manifest_json_is_valid(self, tiny_manifest, tmp_path):
        _, manifest = tiny_manifest
        path = tmp_path / "manifest.json"
        path.write_text(json.dumps({"variants": {"tiny": manifest}}))
        loaded = json.loads(path.read_text())
        assert loaded["variants"]["tiny"]["n"] == 64
